//! Quickstart: load the AOT artifacts, stand up one edge-cloud pipeline,
//! and run a few frames through it.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{PauseResume, RouteOutcome};
use neukonfig::device::FrameSource;
use neukonfig::metrics::fmt_duration;

fn main() -> Result<()> {
    // 1. Load the artifact index (built once by `make artifacts`; Python
    //    never runs again after that).
    let setup = ExperimentSetup::load()?;
    println!("models available: {:?}", setup.index.models);

    // 2. Build an edge-cloud environment for MobileNetV2 and deploy a
    //    pipeline split at the optimum for 20 Mbps.
    let env = setup.env("mobilenetv2")?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let split = profile.optimal_split(
        setup.cfg.network.high_mbps,
        setup.cfg.network.latency,
        1.0,
    );
    println!(
        "deploying pipeline: edge runs units 0..{split}, cloud runs {split}..{}",
        env.manifest.num_layers()
    );
    let strat = PauseResume::deploy(env.clone(), split)?;
    let p = strat.router.active();
    println!(
        "pipeline up: container start {} + compile {} + weights {}",
        fmt_duration(p.init_stats.container_start),
        fmt_duration(p.init_stats.compile),
        fmt_duration(p.init_stats.weights_upload),
    );

    // 3. Stream a few camera frames through it.
    let mut cam = FrameSource::new(&env.manifest.input_shape, 15.0, 42);
    for _ in 0..5 {
        let frame = cam.next_frame();
        let lit = env.frame_literal(&frame)?;
        match strat.router.route(&lit)? {
            RouteOutcome::Processed(rep) => {
                let probs = rep.output.to_vec::<f32>()?;
                let (top, conf) = probs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, &p)| (i, p))
                    .unwrap();
                println!(
                    "frame {:>2}: class {top:>3} ({conf:.3})  T_e={} T_t={} T_c={} total={}",
                    frame.id,
                    fmt_duration(rep.t_edge),
                    fmt_duration(rep.t_transfer),
                    fmt_duration(rep.t_cloud),
                    fmt_duration(rep.total()),
                );
            }
            RouteOutcome::Degraded(rep) => {
                println!("frame {:>2}: served edge-only (degraded), T_e={}", frame.id, fmt_duration(rep.t_edge));
            }
            RouteOutcome::DroppedPaused => println!("frame {} dropped (paused)", frame.id),
            RouteOutcome::DroppedFaulted => println!("frame {} dropped (link fault)", frame.id),
        }
    }

    let s = strat.router.stats.snapshot();
    println!(
        "done: {} produced, {} processed, {} dropped",
        s.produced, s.processed, s.dropped
    );
    Ok(())
}
