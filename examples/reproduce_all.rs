//! Regenerate every paper figure and table, writing markdown results to
//! stdout (redirect into EXPERIMENTS.md sections).
//!
//! ```bash
//! cargo run --release --example reproduce_all > /tmp/results.md
//! cargo run --release --example reproduce_all -- --quick   # smaller grids
//! ```

use std::time::Duration;

use anyhow::Result;
use neukonfig::coordinator::experiments::{
    downtime_grid, frame_drop_rows, measure_downtime, partition_sweep, table1_memory, Approach,
    ExperimentSetup, GridCell,
};
use neukonfig::coordinator::PlacementCase;
use neukonfig::metrics::{fmt_duration, Table};
use neukonfig::stress::StressProfile;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let setup = ExperimentSetup::load()?;
    let cfg = setup.cfg.clone();

    println!("# NEUKONFIG reproduction results\n");
    println!(
        "Config: {}/{} Mbps, {} ms latency, pipeline {} MB, quick={quick}\n",
        cfg.network.high_mbps,
        cfg.network.low_mbps,
        cfg.network.latency.as_millis(),
        cfg.memory.pipeline_mb
    );

    // ---------------- Fig 2 / Fig 3: partition sweeps -------------------
    for (model, fig) in [("vgg19", "Fig 2"), ("mobilenetv2", "Fig 3")] {
        let env = setup.env(model)?;
        eprintln!("[{fig}] profiling {model}...");
        let profile = setup.measured_profile(&env, if quick { 2 } else { 5 })?;
        for bw in [cfg.network.high_mbps, cfg.network.low_mbps] {
            let rows = partition_sweep(&profile, bw, cfg.network.latency);
            let opt = rows.iter().find(|r| r.optimal).unwrap();
            let mut t = Table::new(
                &format!("{fig}: {model} @ {bw} Mbps (optimal split = {} [{}])", opt.split, opt.layer),
                &["split", "after", "edge ms", "transfer ms", "cloud ms", "total ms", "out KB"],
            );
            for r in &rows {
                t.row(vec![
                    format!("{}{}", r.split, if r.optimal { "*" } else { "" }),
                    r.layer.clone(),
                    format!("{:.1}", r.edge_s * 1e3),
                    format!("{:.1}", r.transfer_s * 1e3),
                    format!("{:.1}", r.cloud_s * 1e3),
                    format!("{:.1}", r.total_s * 1e3),
                    format!("{:.1}", r.out_kb),
                ]);
            }
            println!("{}", t.to_markdown());
        }
    }

    // ------------- Fig 11/12/13: downtime grids -------------------------
    let model = "mobilenetv2";
    let env = setup.env(model)?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let approaches: [(Approach, &str, &str); 5] = [
        (Approach::PauseResume, "Fig 11", "~6 s, flat; empty at 10% mem"),
        (Approach::ScenarioA(PlacementCase::NewContainer), "Fig 12 (case 1)", "< 0.98 ms"),
        (Approach::ScenarioA(PlacementCase::SameContainer), "Fig 12 (case 2)", "< 0.98 ms"),
        (Approach::ScenarioB(PlacementCase::NewContainer), "Fig 13 (case 1)", "~1.9 s"),
        (Approach::ScenarioB(PlacementCase::SameContainer), "Fig 13 (case 2)", "~0.6 s"),
    ];
    for (approach, fig, paper) in approaches {
        for (from, to, dir) in [
            (cfg.network.high_mbps, cfg.network.low_mbps, "20->5 Mbps"),
            (cfg.network.low_mbps, cfg.network.high_mbps, "5->20 Mbps"),
        ] {
            eprintln!("[{fig}] {} {dir}...", approach.label());
            let cells: Vec<GridCell> = if quick {
                // Corners of the grid only.
                let mut v = Vec::new();
                for sp in [
                    StressProfile::new(0.25, 0.10),
                    StressProfile::new(0.25, 1.0),
                    StressProfile::new(1.0, 0.10),
                    StressProfile::new(1.0, 1.0),
                ] {
                    let downtime =
                        measure_downtime(&env, &profile, approach, sp, from, to)?;
                    v.push(GridCell {
                        cpu_avail: sp.cpu_avail,
                        mem_avail: sp.mem_avail,
                        downtime,
                    });
                }
                v
            } else {
                downtime_grid(&env, &profile, approach, from, to)?
            };
            let mut t = Table::new(
                &format!("{fig}: {} downtime, {dir} (paper: {paper})", approach.label()),
                &["cpu %", "mem %", "downtime", "real", "simulated"],
            );
            for c in &cells {
                match &c.downtime {
                    Some(d) => t.row(vec![
                        format!("{:.0}", c.cpu_avail * 100.0),
                        format!("{:.0}", c.mem_avail * 100.0),
                        fmt_duration(d.total),
                        fmt_duration(d.real()),
                        fmt_duration(d.simulated),
                    ]),
                    None => t.row(vec![
                        format!("{:.0}", c.cpu_avail * 100.0),
                        format!("{:.0}", c.mem_avail * 100.0),
                        "no result (OOM)".into(),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
            println!("{}", t.to_markdown());
        }
    }

    // ------------- Fig 14/15: frame drop during downtime ----------------
    // Use the measured downtimes at full availability.
    let fps_list = [5.0, 10.0, 15.0, 20.0, 25.0, 30.0];
    for (bw_from, bw_to, fig) in [
        (cfg.network.low_mbps, cfg.network.high_mbps, "Fig 14 (@20 Mbps)"),
        (cfg.network.high_mbps, cfg.network.low_mbps, "Fig 15 (@5 Mbps)"),
    ] {
        let mut t = Table::new(
            &format!("{fig}: frame drop rate during downtime"),
            &["approach", "downtime", "fps", "arrivals", "served", "dropped", "rate"],
        );
        for approach in [
            Approach::PauseResume,
            Approach::ScenarioA(PlacementCase::SameContainer),
            Approach::ScenarioB(PlacementCase::NewContainer),
            Approach::ScenarioB(PlacementCase::SameContainer),
        ] {
            let rec = measure_downtime(
                &env,
                &profile,
                approach,
                StressProfile::none(),
                bw_from,
                bw_to,
            )?
            .expect("fits");
            for row in
                frame_drop_rows(&profile, &cfg, approach, rec.total, bw_from, bw_to, &fps_list)
            {
                t.row(vec![
                    row.approach.to_string(),
                    fmt_duration(Duration::from_secs_f64(row.downtime_s)),
                    format!("{:.0}", row.fps),
                    row.outcome.arrivals.to_string(),
                    row.outcome.served.to_string(),
                    row.outcome.dropped.to_string(),
                    format!("{:.2}", row.outcome.drop_rate()),
                ]);
            }
        }
        println!("{}", t.to_markdown());
    }

    // ------------- Table I: memory -------------------------------------
    eprintln!("[Table I] memory accounting...");
    let rows = table1_memory(&setup, model)?;
    let mut t = Table::new(
        "Table I: total resources (paper: 763.1 / 1526.2 / 763.1 / 1526.2-transient / 763.1 MB)",
        &["approach", "initial MB", "additional MB", "peak MB", "transient"],
    );
    for r in rows {
        t.row(vec![
            r.approach.to_string(),
            format!("{:.1}", r.initial_mb),
            format!("{:.1}", r.additional_mb),
            format!("{:.1}", r.peak_mb),
            if r.transient { "yes (during switching only)".into() } else { "no".into() },
        ]);
    }
    println!("{}", t.to_markdown());

    eprintln!("done.");
    Ok(())
}
