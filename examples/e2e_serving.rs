//! End-to-end serving driver (the mandated E2E validation run).
//!
//! Loads the VGG-19 artifacts, serves synthetic camera frames at 15 FPS in
//! REAL TIME through an edge-cloud pipeline while the network toggles
//! 20 -> 5 -> 20 Mbps, and repartitions with the selected strategy on each
//! change. Reports latency/throughput/downtime/frame-drop per strategy.
//!
//! ```bash
//! cargo run --release --example e2e_serving            # all strategies
//! cargo run --release --example e2e_serving -- --model mobilenetv2 \
//!     --fps 15 --period-s 6 --strategy scenario-a-case2
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use neukonfig::clock::Clock;
use neukonfig::config::ExperimentConfig;
use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{
    EdgeCloudEnv, NetworkMonitor, PauseResume, PlacementCase, Planner, RouteOutcome, ScenarioA,
    ScenarioB,
};
use neukonfig::device::FrameSource;
use neukonfig::metrics::fmt_duration;
use neukonfig::netsim::Schedule;
use neukonfig::profiler::ModelProfile;

#[derive(Clone, Copy, PartialEq)]
enum Strategy {
    PauseResume,
    A1,
    A2,
    B1,
    B2,
}

impl Strategy {
    fn label(self) -> &'static str {
        match self {
            Strategy::PauseResume => "pause-resume",
            Strategy::A1 => "scenario-a-case1",
            Strategy::A2 => "scenario-a-case2",
            Strategy::B1 => "scenario-b-case1",
            Strategy::B2 => "scenario-b-case2",
        }
    }
}

fn arg(key: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let model = arg("--model", "vgg19");
    let fps: f64 = arg("--fps", "15").parse()?;
    let period_s: u64 = arg("--period-s", "6").parse()?;
    let only = arg("--strategy", "all");

    let strategies = [
        Strategy::PauseResume,
        Strategy::A2,
        Strategy::B1,
        Strategy::B2,
        Strategy::A1,
    ];
    let setup = ExperimentSetup::load()?;

    println!(
        "# E2E serving: {model} @ {fps} FPS, network toggles {}->{}->{} Mbps every {period_s}s\n",
        setup.cfg.network.high_mbps, setup.cfg.network.low_mbps, setup.cfg.network.high_mbps
    );

    for strat in strategies {
        if only != "all" && only != strat.label() {
            continue;
        }
        run_one(&setup, &model, strat, fps, Duration::from_secs(period_s))?;
    }
    Ok(())
}

fn run_one(
    setup: &ExperimentSetup,
    model: &str,
    strategy: Strategy,
    fps: f64,
    period: Duration,
) -> Result<()> {
    // Realtime clock: sleeps are real, downtime is wall time.
    let manifest = setup.manifest(model)?;
    let env = Arc::new(EdgeCloudEnv::new(
        ExperimentConfig::new(),
        manifest,
        Clock::realtime(),
    )?);
    let cfg = &env.cfg;
    let profile: ModelProfile = neukonfig::profiler::default_analytic(&env.manifest);
    let planner = Planner::new(profile.clone(), cfg.network.latency);
    let hi = planner.plan(cfg.network.high_mbps).split;
    let lo = planner.plan(cfg.network.low_mbps).split;

    eprintln!("[{}] deploying (splits {hi}<->{lo})...", strategy.label());

    enum Deployed {
        P(PauseResume),
        A(ScenarioA),
        B(ScenarioB),
    }
    let deployed = match strategy {
        Strategy::PauseResume => Deployed::P(PauseResume::deploy(env.clone(), hi)?),
        Strategy::A1 => Deployed::A(ScenarioA::deploy(
            env.clone(),
            hi,
            lo,
            PlacementCase::NewContainer,
        )?),
        Strategy::A2 => Deployed::A(ScenarioA::deploy(
            env.clone(),
            hi,
            lo,
            PlacementCase::SameContainer,
        )?),
        Strategy::B1 => Deployed::B(
            ScenarioB::deploy(env.clone(), hi)?.with_case(PlacementCase::NewContainer),
        ),
        Strategy::B2 => Deployed::B(
            ScenarioB::deploy(env.clone(), hi)?.with_case(PlacementCase::SameContainer),
        ),
    };
    let router = match &deployed {
        Deployed::P(s) => s.router.clone(),
        Deployed::A(s) => s.router.clone(),
        Deployed::B(s) => s.router.clone(),
    };

    // Network trace: toggle twice (20 -> 5 at t=period, 5 -> 20 at 2*period).
    let monitor = NetworkMonitor::new(
        env.link.clone(),
        Schedule::toggle(cfg.network.high_mbps, cfg.network.low_mbps, period, 2),
    );

    let total_run = period * 3;
    let mut cam = FrameSource::new(&env.manifest.input_shape, fps, cfg.seed);
    let clock = env.clock.clone();
    let mut downtimes = Vec::new();
    let started = clock.now();

    // Serving loop: paced frame production, repartition on monitor events.
    while clock.now() - started < total_run {
        let now = clock.now() - started;
        if let Some(change) = monitor.poll(now) {
            let current = router.active().split;
            if let Some(plan) = planner.should_repartition(current, change.to_mbps) {
                eprintln!(
                    "[{}] t={:.1}s bandwidth {}->{} Mbps: repartition {} -> {}",
                    strategy.label(),
                    now.as_secs_f64(),
                    change.from_mbps,
                    change.to_mbps,
                    current,
                    plan.split
                );
                let rec = match &deployed {
                    Deployed::P(s) => s.repartition(plan.split)?,
                    Deployed::A(s) => s.switch()?,
                    Deployed::B(s) => s.repartition(plan.split)?,
                };
                eprintln!(
                    "[{}]   downtime {}",
                    strategy.label(),
                    fmt_duration(rec.total)
                );
                downtimes.push(rec);
            }
        }

        // Produce the frame due now (drop if we're behind schedule).
        let frame = cam.next_frame();
        let lit = env.frame_literal(&frame)?;
        match router.route(&lit) {
            Ok(RouteOutcome::Processed(_) | RouteOutcome::Degraded(_)) => {}
            Ok(RouteOutcome::DroppedPaused | RouteOutcome::DroppedFaulted) => {}
            Err(e) => eprintln!("[{}] route error: {e}", strategy.label()),
        }

        // Pace to the camera rate.
        let next_due = frame.captured_at + cam.interval();
        let now = clock.now() - started;
        if next_due > now {
            std::thread::sleep(next_due - now);
        }
    }

    let s = router.stats.snapshot();
    let elapsed = (clock.now() - started).as_secs_f64();
    let summary = router.latency.summary();
    println!("## {}", strategy.label());
    println!(
        "- frames: {} produced, {} processed, {} dropped ({} during downtime)",
        s.produced, s.processed, s.dropped, s.dropped_during_downtime
    );
    println!(
        "- throughput: {:.1} frames/s over {elapsed:.1} s",
        s.processed as f64 / elapsed
    );
    if let Some(sum) = summary {
        println!(
            "- e2e latency: mean {} p50 {} p95 {} max {}",
            fmt_duration(Duration::from_secs_f64(sum.mean)),
            fmt_duration(Duration::from_secs_f64(sum.p50)),
            fmt_duration(Duration::from_secs_f64(sum.p95)),
            fmt_duration(Duration::from_secs_f64(sum.max)),
        );
    }
    for (i, d) in downtimes.iter().enumerate() {
        println!(
            "- downtime {}: {} (real {}, simulated {})",
            i + 1,
            fmt_duration(d.total),
            fmt_duration(d.real()),
            fmt_duration(d.simulated)
        );
    }
    println!();
    Ok(())
}
