//! Profile both models per layer on the edge and cloud domains and print
//! the Fig 2 / Fig 3 partition sweeps at 20 and 5 Mbps.
//!
//! ```bash
//! cargo run --release --example profile_models
//! ```

use anyhow::Result;
use neukonfig::coordinator::experiments::{partition_sweep, ExperimentSetup};
use neukonfig::metrics::{fmt_duration, Table};
use std::time::Duration;

fn main() -> Result<()> {
    let setup = ExperimentSetup::load()?;
    for model in ["vgg19", "mobilenetv2"] {
        let env = setup.env(model)?;
        eprintln!("profiling {model} (real per-layer execution)...");
        let profile = setup.measured_profile(&env, 5)?;

        let mut t = Table::new(
            &format!("{model}: per-layer profile"),
            &["#", "layer", "kind", "edge", "cloud", "out KB"],
        );
        for l in &profile.layers {
            t.row(vec![
                l.index.to_string(),
                l.name.clone(),
                l.kind.clone(),
                fmt_duration(l.edge_time),
                fmt_duration(l.cloud_time),
                format!("{:.1}", l.output_bytes as f64 / 1024.0),
            ]);
        }
        println!("{}", t.to_markdown());

        for bw in [setup.cfg.network.high_mbps, setup.cfg.network.low_mbps] {
            let rows = partition_sweep(&profile, bw, setup.cfg.network.latency);
            let mut t = Table::new(
                &format!("{model}: Eq-1 sweep @ {bw} Mbps"),
                &["split", "after", "edge", "transfer", "cloud", "total", "opt"],
            );
            for r in rows {
                t.row(vec![
                    r.split.to_string(),
                    r.layer,
                    fmt_duration(Duration::from_secs_f64(r.edge_s)),
                    fmt_duration(Duration::from_secs_f64(r.transfer_s)),
                    fmt_duration(Duration::from_secs_f64(r.cloud_s)),
                    fmt_duration(Duration::from_secs_f64(r.total_s)),
                    if r.optimal { "<-- optimal".into() } else { String::new() },
                ]);
            }
            println!("{}", t.to_markdown());
        }
    }
    Ok(())
}
