//! Side-by-side comparison of all repartitioning approaches on one
//! network-speed change (simulated clock; real PJRT work).
//!
//! ```bash
//! cargo run --release --example repartition_demo -- --model mobilenetv2
//! ```

use anyhow::Result;
use neukonfig::coordinator::experiments::{
    frame_drop_rows, measure_downtime, Approach, ExperimentSetup,
};
use neukonfig::coordinator::PlacementCase;
use neukonfig::metrics::{fmt_duration, Table};
use neukonfig::stress::StressProfile;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "mobilenetv2".to_string());

    let setup = ExperimentSetup::load()?;
    let env = setup.env(&model)?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let cfg = &setup.cfg;

    println!(
        "# Repartition demo: {model}, {} -> {} Mbps\n",
        cfg.network.high_mbps, cfg.network.low_mbps
    );

    let approaches = [
        Approach::PauseResume,
        Approach::ScenarioA(PlacementCase::NewContainer),
        Approach::ScenarioA(PlacementCase::SameContainer),
        Approach::ScenarioB(PlacementCase::NewContainer),
        Approach::ScenarioB(PlacementCase::SameContainer),
    ];

    let mut t = Table::new(
        "Downtime per approach (paper: 6 s / <1 ms / <1 ms / 1.9 s / 0.6 s)",
        &["approach", "downtime", "real", "simulated", "phases"],
    );
    let mut downtimes = Vec::new();
    for a in approaches {
        let rec = measure_downtime(
            &env,
            &profile,
            a,
            StressProfile::none(),
            cfg.network.high_mbps,
            cfg.network.low_mbps,
        )?
        .expect("no OOM at full availability");
        let phases = rec
            .phases
            .iter()
            .map(|(n, d)| format!("{n}={}", fmt_duration(*d)))
            .collect::<Vec<_>>()
            .join(", ");
        t.row(vec![
            a.label().to_string(),
            fmt_duration(rec.total),
            fmt_duration(rec.real()),
            fmt_duration(rec.simulated),
            phases,
        ]);
        downtimes.push((a, rec));
    }
    println!("{}", t.to_markdown());

    // Frame drops during each approach's downtime at 15 and 30 FPS.
    let mut t = Table::new(
        "Frames dropped during the downtime window",
        &["approach", "fps", "arrivals", "served", "dropped", "drop rate"],
    );
    for (a, rec) in &downtimes {
        for row in frame_drop_rows(
            &profile,
            cfg,
            *a,
            rec.total,
            cfg.network.high_mbps,
            cfg.network.low_mbps,
            &[15.0, 30.0],
        ) {
            t.row(vec![
                row.approach.to_string(),
                format!("{:.0}", row.fps),
                row.outcome.arrivals.to_string(),
                row.outcome.served.to_string(),
                row.outcome.dropped.to_string(),
                format!("{:.2}", row.outcome.drop_rate()),
            ]);
        }
    }
    println!("{}", t.to_markdown());
    Ok(())
}
