//! Fig 2: VGG-19 end-to-end latency + transfer size per partition point at
//! 20 and 5 Mbps. Paper result: the optimal split moves deeper (L17 -> L22)
//! when the bandwidth drops.

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{partition_sweep, ExperimentSetup};
use neukonfig::metrics::Table;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env("vgg19")?;
    eprintln!("profiling vgg19 ({} units, real execution)...", env.manifest.num_layers());
    let profile = setup.measured_profile(&env, if common::quick() { 2 } else { 5 })?;

    let mut report = Report::new("Fig 2: VGG-19 partition sweep");
    let mut optima = Vec::new();
    for bw in [setup.cfg.network.high_mbps, setup.cfg.network.low_mbps] {
        let rows = partition_sweep(&profile, bw, setup.cfg.network.latency);
        let opt = rows.iter().find(|r| r.optimal).unwrap().clone();
        let mut t = Table::new(
            &format!("@ {bw} Mbps — optimal split {} ({})", opt.split, opt.layer),
            &["split", "after", "edge ms", "xfer ms", "cloud ms", "total ms", "out KB"],
        );
        for r in &rows {
            t.row(vec![
                format!("{}{}", r.split, if r.optimal { "*" } else { "" }),
                r.layer.clone(),
                format!("{:.1}", r.edge_s * 1e3),
                format!("{:.1}", r.transfer_s * 1e3),
                format!("{:.1}", r.cloud_s * 1e3),
                format!("{:.1}", r.total_s * 1e3),
                format!("{:.1}", r.out_kb),
            ]);
        }
        report.table(t);
        optima.push(opt.split);
    }
    report.note(format!(
        "measured optimal split: {} @ 20 Mbps -> {} @ 5 Mbps (paper: 17 -> 22; \
         same qualitative shift: lower bandwidth pushes the split deeper)",
        optima[0], optima[1]
    ));
    assert!(
        optima[1] >= optima[0],
        "SHAPE CHECK FAILED: split should move deeper at lower bandwidth"
    );
    report.print();
    Ok(())
}
