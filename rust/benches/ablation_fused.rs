//! Ablation: per-layer partition artifacts vs one fused HLO per partition.
//!
//! DESIGN.md's key design choice is exporting ONE HLO module per partition
//! unit so a repartition re-chains cached executables instead of compiling
//! anything. The alternative — fusing each partition side into a single
//! module — gives XLA a whole-subgraph fusion scope (potentially faster
//! steady-state) but pins the split at compile time, so every repartition
//! pays a fresh compile. This bench measures both sides of that trade.

mod common;

use neukonfig::bench::{bench, BenchConfig, Report};
use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::metrics::{fmt_duration, Table};
use neukonfig::runtime::{build_fused_exec, literal_from_f32, ChainExecutor, Domain};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let setup = ExperimentSetup::load()?;
    let mut report = Report::new("Ablation: per-layer chain vs fused partition");
    let mut t = Table::new(
        "",
        &["model", "variant", "exec mean", "repartition cost (compile)"],
    );

    for model in ["mobilenetv2", "vgg19"] {
        let manifest = setup.manifest(model)?;
        let Some(entry) = manifest.fused.first().cloned() else {
            eprintln!("{model}: no fused artifacts, skipping");
            continue;
        };
        let domain = Domain::new("edge", 1.0)?;
        let weights = neukonfig::runtime::WeightStore::load(&manifest)?;
        let split = entry.split;

        // Per-layer chain for the edge side of the fused split.
        let chain = ChainExecutor::build(domain.clone(), &manifest, 0..split, &weights)?;
        // Fused single-module executor for the same units.
        let fused = build_fused_exec(domain.clone(), &manifest, &entry, true, &weights)?;

        let numel: usize = manifest.input_shape.iter().product();
        let input = literal_from_f32(&manifest.input_shape, &vec![0.5f32; numel])?;

        // Correctness: both variants must agree.
        let a = chain.run_raw(&input)?.to_vec::<f32>()?;
        let b = fused.run(&input)?.to_vec::<f32>()?;
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() < 1e-4 + x.abs() * 1e-4,
                "{model} fused/chain mismatch at {i}: {x} vs {y}"
            );
        }

        let chain_exec = bench(&format!("{model} chain exec"), &cfg, || {
            chain.run_raw(&input).unwrap();
        });
        let fused_exec = bench(&format!("{model} fused exec"), &cfg, || {
            fused.run(&input).unwrap();
        });

        // Repartition cost: per-layer = warm rebuild (cache hits only);
        // fused = compiling the partition module from scratch (a new split
        // would always be a cache miss — simulate by clearing).
        let warm_t0 = Instant::now();
        ChainExecutor::build(domain.clone(), &manifest, 0..split, &weights)?;
        let chain_repartition = warm_t0.elapsed();

        domain.clear_cache();
        let cold_t0 = Instant::now();
        build_fused_exec(domain.clone(), &manifest, &entry, true, &weights)?;
        let fused_repartition = cold_t0.elapsed();

        t.row(vec![
            model.into(),
            format!("per-layer chain [0..{split})"),
            fmt_duration(Duration::from_secs_f64(chain_exec.summary.mean)),
            fmt_duration(chain_repartition),
        ]);
        t.row(vec![
            model.into(),
            format!("fused module [0..{split})"),
            fmt_duration(Duration::from_secs_f64(fused_exec.summary.mean)),
            fmt_duration(fused_repartition),
        ]);

        eprintln!(
            "{model}: fused/chain exec ratio {:.2}, repartition {:.0}x cheaper per-layer",
            fused_exec.summary.mean / chain_exec.summary.mean,
            fused_repartition.as_secs_f64() / chain_repartition.as_secs_f64().max(1e-9),
        );
    }
    report.table(t);
    report.note(
        "per-layer artifacts trade a small steady-state execution overhead for \
         repartitions that never compile — the property Dynamic Switching's \
         sub-millisecond switch (Scenario A) and ~0.5 s warm init (Scenario B \
         case 2) depend on.",
    );
    report.print();
    Ok(())
}
