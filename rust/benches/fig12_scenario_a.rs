//! Fig 12: Dynamic Switching Scenario A (hot standby) downtime grid.
//! Paper: < 0.98 ms under all CPU/memory availabilities; Case 1 and Case 2
//! identical (initialisation already done).

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{measure_downtime, Approach, ExperimentSetup};
use neukonfig::coordinator::PlacementCase;
use neukonfig::metrics::Table;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env("mobilenetv2")?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let cfg = &setup.cfg;

    let mut report = Report::new("Fig 12: Dynamic Switching Scenario A downtime grid");
    let mut worst = 0.0f64;
    for case in [PlacementCase::NewContainer, PlacementCase::SameContainer] {
        for (from, to, dir) in [
            (cfg.network.high_mbps, cfg.network.low_mbps, "to 5 Mbps"),
            (cfg.network.low_mbps, cfg.network.high_mbps, "to 20 Mbps"),
        ] {
            let label = match case {
                PlacementCase::NewContainer => "case 1 (own containers)",
                PlacementCase::SameContainer => "case 2 (shared container)",
            };
            let mut t = Table::new(
                &format!("{label}, {dir} (paper: < 0.98 ms)"),
                &["cpu %", "mem %", "downtime", "real", "simulated"],
            );
            for sp in common::grid() {
                eprintln!("A {label} cell cpu={:.2} mem={:.2} {dir}", sp.cpu_avail, sp.mem_avail);
                let d = measure_downtime(&env, &profile, Approach::ScenarioA(case), sp, from, to)?;
                if let Some(rec) = &d {
                    worst = worst.max(rec.total.as_secs_f64());
                }
                let mut row = vec![
                    format!("{:.0}", sp.cpu_avail * 100.0),
                    format!("{:.0}", sp.mem_avail * 100.0),
                ];
                row.extend(common::cell_str(&d));
                t.row(row);
            }
            report.table(t);
        }
    }
    report.note(format!(
        "worst-case switch downtime: {:.3} ms (paper: < 0.98 ms)",
        worst * 1e3
    ));
    assert!(worst < 0.98e-3, "scenario A must switch in < 0.98 ms, got {worst}s");
    report.print();
    Ok(())
}
