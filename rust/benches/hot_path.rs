//! Hot-path micro-benchmarks (§Perf): the operations on the request path
//! and the switch path, measured with the in-tree harness.
//!
//! - router switch latency (the t_switch of Equation 3 — paper headline
//!   "< 1 ms"; ours targets < 100 us)
//! - per-frame routing overhead (everything the coordinator adds on top of
//!   PJRT execution)
//! - end-to-end single-frame inference per model
//! - pipeline (re)build: cached vs uncached executables (the §Perf
//!   optimisation and the ablation behind Dynamic Switching's speed)
//! - parallel vs serial bring-up, cached vs uncached weight staging, and
//!   overlapped vs sequential frame throughput (the perf layer)
//! - 2-stage vs 3-stage pipelining on a transfer-bound configuration
//!   (realtime clock, split at the fattest intermediate tensor)
//!
//! Also emits `BENCH_hot_path.json`, the machine-readable baseline the CI
//! bench gate (`bench_gate`) diffs against.

mod common;

use std::sync::Arc;

use neukonfig::bench::{bench, bench_measured, BenchConfig, Report};
use neukonfig::clock::Clock;
use neukonfig::codec::TransferCodec;
use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{
    EdgeCloudEnv, PipelinedRunner, PipelineState, PlacementCase, Placement, Planner, ScenarioA,
};
use neukonfig::device::FrameSource;
use neukonfig::metrics::{fmt_duration, Table};
use neukonfig::runtime::{BuildOptions, ChainExecutor};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let setup = ExperimentSetup::load()?;
    let env = setup.env("mobilenetv2")?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let net = &setup.cfg.network;
    let hi = profile.optimal_split(net.high_mbps, net.latency, 1.0);
    let lo = profile.optimal_split(net.low_mbps, net.latency, 1.0);

    let mut report = Report::new("Hot-path micro-benchmarks (§Perf)");
    let mut t = Table::new(
        "",
        &["operation", "mean", "p50", "p95", "max", "n"],
    );
    let mut all: Vec<neukonfig::bench::BenchResult> = Vec::new();
    let mut push = |r: neukonfig::bench::BenchResult| {
        let s = &r.summary;
        t.row(vec![
            r.name.clone(),
            fmt_duration(std::time::Duration::from_secs_f64(s.mean)),
            fmt_duration(std::time::Duration::from_secs_f64(s.p50)),
            fmt_duration(std::time::Duration::from_secs_f64(s.p95)),
            fmt_duration(std::time::Duration::from_secs_f64(s.max)),
            s.n.to_string(),
        ]);
        all.push(r.clone());
        r
    };

    // --- switch latency (Scenario A toggle; measured on the clock) ------
    let strat = ScenarioA::deploy(env.clone(), hi, lo, PlacementCase::SameContainer)?;
    let switch = push(bench_measured("router switch (t_switch)", &cfg, || {
        strat.switch().unwrap().total
    }));

    // --- per-frame end-to-end inference ---------------------------------
    let mut cam = FrameSource::new(&env.manifest.input_shape, 15.0, 1);
    let frame = cam.next_frame();
    let lit = env.frame_literal(&frame)?;
    let router = strat.router.clone();
    push(bench("frame e2e (route+edge+link+cloud)", &cfg, || {
        router.route(&lit).unwrap();
    }));

    // --- routing overhead: route minus raw chain execution --------------
    let active = router.active();
    push(bench("raw chains only (no router/link)", &cfg, || {
        let mid = active.edge_chain.run_raw(&lit).unwrap();
        active.cloud_chain.run_raw(&mid).unwrap();
    }));

    // --- pipeline rebuild: cached vs uncached ----------------------------
    let n = env.manifest.num_layers();
    let rebuild_cached = push(bench("chain rebuild (cached exes)", &cfg, || {
        ChainExecutor::build(env.edge.clone(), &env.manifest, 0..n, &env.weights).unwrap();
    }));
    let rebuild_uncached = push(bench("chain rebuild (uncached — naive app)", &cfg, || {
        ChainExecutor::build_uncached(env.edge.clone(), &env.manifest, 0..n, &env.weights)
            .unwrap();
    }));

    // --- bring-up: serial vs parallel worker pool ------------------------
    // Uncached so every iteration pays real compilation + staging — the
    // work the pool actually parallelises.
    let bringup_serial = push(bench("bring-up serial (uncached)", &cfg, || {
        ChainExecutor::build_with(
            env.edge.clone(),
            &env.manifest,
            0..n,
            &env.weights,
            BuildOptions::serial(false),
        )
        .unwrap();
    }));
    let bringup_parallel = push(bench("bring-up parallel (uncached)", &cfg, || {
        ChainExecutor::build_with(
            env.edge.clone(),
            &env.manifest,
            0..n,
            &env.weights,
            BuildOptions::parallel(false),
        )
        .unwrap();
    }));

    // --- weight staging: cold vs warm device-buffer cache ----------------
    let staging_cold = push(bench("weight staging (cold cache)", &cfg, || {
        env.edge.clear_weight_cache();
        for layer in &env.manifest.layers {
            env.edge
                .layer_weight_buffers(&env.weights, layer, true)
                .unwrap();
        }
    }));
    let staging_warm = push(bench("weight staging (warm cache)", &cfg, || {
        for layer in &env.manifest.layers {
            env.edge
                .layer_weight_buffers(&env.weights, layer, true)
                .unwrap();
        }
    }));

    // --- frame throughput: sequential vs overlapped ----------------------
    const BURST: usize = 8;
    let frames: Vec<_> = (0..BURST)
        .map(|i| env.frame_literal(&cam.frame(i as u64)).unwrap())
        .collect();
    let runner = PipelinedRunner::new(2);
    {
        // Sanity: overlapped execution must be output-identical, in order.
        let seq: Vec<Vec<f32>> = frames
            .iter()
            .map(|f| active.infer(f).unwrap().output.to_vec::<f32>().unwrap())
            .collect();
        let piped = runner.run(&active, &frames).unwrap();
        assert_eq!(piped.len(), BURST);
        for (s, p) in seq.iter().zip(&piped) {
            assert_eq!(s, &p.output.to_vec::<f32>().unwrap(), "overlap changed outputs");
        }
    }
    let seq_burst = push(bench(&format!("{BURST}-frame burst, sequential"), &cfg, || {
        for f in &frames {
            active.infer(f).unwrap();
        }
    }));
    let piped_burst = push(bench(
        &format!("{BURST}-frame burst, pipelined (3-stage, depth 2)"),
        &cfg,
        || {
            runner.run(&active, &frames).unwrap();
        },
    ));

    // --- 2-stage vs 3-stage on a transfer-bound configuration ------------
    // Realtime clock so simulated transfer cost is real wall time (sim
    // bring-up costs zeroed so nothing else sleeps); split at the fattest
    // intermediate tensor so the wire dominates. The dedicated transfer
    // stage overlaps link time with both neighbours, so 3-stage throughput
    // should match or beat 2-stage here.
    let mut tb_cfg = setup.cfg.clone().without_sim_costs();
    tb_cfg.network.high_mbps = 2_000.0;
    let tb_env = EdgeCloudEnv::new(tb_cfg, setup.manifest("mobilenetv2")?, Clock::realtime())?;
    let tb_n = tb_env.manifest.num_layers();
    let tb_split = (1..tb_n)
        .max_by_key(|&k| tb_env.manifest.transfer_bytes(k))
        .unwrap_or(tb_n / 2);
    let tb = tb_env.build_pipeline(tb_split, Placement::NewContainers)?;
    tb.transition(PipelineState::Active)?;
    let tb_frames: Vec<_> = (0..BURST)
        .map(|i| tb_env.frame_literal(&cam.frame(100 + i as u64)).unwrap())
        .collect();
    let tb_two = push(bench(
        &format!("{BURST}-frame transfer-bound burst, 2-stage"),
        &cfg,
        || {
            PipelinedRunner::two_stage(2).run(&tb, &tb_frames).unwrap();
        },
    ));
    let tb_three = push(bench(
        &format!("{BURST}-frame transfer-bound burst, 3-stage"),
        &cfg,
        || {
            PipelinedRunner::new(2).run(&tb, &tb_frames).unwrap();
        },
    ));

    // --- transfer codec: wire cost at low/high bandwidth ------------------
    // Simulated clock so the measured t_transfer is the link's priced cost
    // (queueing + serialisation of the *encoded* payload), not wall time;
    // split at the fattest intermediate so the codec has the most bytes to
    // shrink. Row names deliberately omit the split so the bench-gate
    // baseline survives profile recalibration.
    let cc_env = setup.env("mobilenetv2")?;
    let cc_n = cc_env.manifest.num_layers();
    let cc_split = (1..cc_n)
        .max_by_key(|&k| cc_env.manifest.transfer_bytes(k))
        .unwrap_or(cc_n / 2);
    let cc_frame = cc_env.frame_literal(&cam.frame(200))?;
    let mut codec_rows: Vec<(TransferCodec, f64, f64)> = Vec::new();
    for &mbps in &[net.low_mbps, net.high_mbps] {
        for codec in [TransferCodec::Fp32, TransferCodec::Fp16, TransferCodec::Int8] {
            cc_env.link.set_bandwidth(mbps);
            // Scoped per iteration: the containers' memory reservations
            // release before the next pipeline starts.
            let mut p = cc_env.build_pipeline(cc_split, Placement::NewContainers)?;
            p.codec = codec;
            p.transition(PipelineState::Active)?;
            let r = push(bench_measured(
                &format!(
                    "frame transfer, {} @ {mbps:.0} Mbps (fattest split)",
                    codec.label()
                ),
                &cfg,
                || p.infer(&cc_frame).unwrap().t_transfer,
            ));
            codec_rows.push((codec, mbps, r.summary.mean));
        }
    }
    let codec_mean = |codec: TransferCodec, mbps: f64| {
        codec_rows
            .iter()
            .find(|(c, b, _)| *c == codec && *b == mbps)
            .unwrap()
            .2
    };

    // Codec-aware planning must actually move an optimum somewhere in the
    // model zoo, otherwise the planner integration is dead weight.
    let mut split_notes = Vec::new();
    let mut any_split_moved = false;
    for model in &setup.index.models {
        let prof = neukonfig::profiler::default_analytic(&setup.manifest(model)?);
        for &mbps in &[net.low_mbps, net.high_mbps] {
            let fp32 = Planner::new(prof.clone(), net.latency)
                .with_codec(TransferCodec::Fp32)
                .plan(mbps)
                .split;
            let int8 = Planner::new(prof.clone(), net.latency)
                .with_codec(TransferCodec::Int8)
                .plan(mbps)
                .split;
            any_split_moved |= int8 != fp32;
            split_notes.push(format!("{model} @ {mbps:.0} Mbps: fp32 k={fp32}, int8 k={int8}"));
        }
    }
    assert!(
        any_split_moved,
        "int8 planning should move at least one optimum: {split_notes:?}"
    );

    // --- faulted link: retrying transfers vs the clean fast path ----------
    // Same simulated-clock env and fattest split as the codec rows. The
    // clean row goes through the retry wrapper's fast path (no plan
    // installed — cost-identical to an unwrapped transfer); the lossy row
    // prices 1 % chunk loss with redone attempts + backoff. The window
    // outlives any bench run on the simulated timeline, and 8 attempts
    // make exhaustion at 1 % loss effectively impossible, so the row
    // never drops a frame.
    cc_env.link.set_bandwidth(net.high_mbps);
    cc_env.link.clear_fault_plan(); // the clean row must actually be clean
    let mut fp = cc_env.build_pipeline(cc_split, Placement::NewContainers)?;
    fp.retry = neukonfig::netsim::RetryPolicy {
        max_attempts: 8,
        base_backoff: std::time::Duration::from_millis(5),
        deadline: None,
    };
    fp.transition(PipelineState::Active)?;
    let xfer_clean = push(bench_measured(
        &format!("frame transfer, fp32 @ {:.0} Mbps (clean link)", net.high_mbps),
        &cfg,
        || {
            let r = fp.infer(&cc_frame).unwrap();
            r.t_transfer + r.t_backoff
        },
    ));
    cc_env
        .link
        .install_fault_plan(neukonfig::netsim::FaultPlan::parse(
            "loss:0.01@0..1000000000",
            0xB3,
        ));
    let xfer_lossy = push(bench_measured(
        &format!(
            "frame transfer, fp32 @ {:.0} Mbps (1% chunk loss, retries)",
            net.high_mbps
        ),
        &cfg,
        || {
            let r = fp.infer(&cc_frame).unwrap();
            r.t_transfer + r.t_backoff
        },
    ));
    let fault_counters = cc_env.link.fault_counters();
    cc_env.link.clear_fault_plan();
    assert!(
        xfer_lossy.summary.mean >= xfer_clean.summary.mean,
        "injected loss can only add transfer cost"
    );

    // --- container-sim control plane ------------------------------------
    push(bench_measured("pipeline init, same container (B2 init)", &cfg, || {
        let active = router.active();
        let p = env
            .build_pipeline(
                lo,
                Placement::Existing {
                    edge: active.edge_container.clone(),
                    cloud: active.cloud_container.clone(),
                },
            )
            .unwrap();
        p.init_stats.total
    }));

    report.table(t);
    report.note(format!(
        "switch mean {} — paper's Scenario A headline is < 0.98 ms; \
         cache speedup for rebuild: {:.0}x (the ablation behind Dynamic Switching)",
        fmt_duration(std::time::Duration::from_secs_f64(switch.summary.mean)),
        rebuild_uncached.summary.mean / rebuild_cached.summary.mean.max(1e-9),
    ));
    report.note(format!(
        "perf layer: parallel bring-up {:.2}x vs serial; warm weight cache \
         {:.0}x vs cold staging; pipelined burst {:.2}x throughput \
         ({:.1} vs {:.1} frames/s)",
        bringup_serial.summary.mean / bringup_parallel.summary.mean.max(1e-9),
        staging_cold.summary.mean / staging_warm.summary.mean.max(1e-9),
        seq_burst.summary.mean / piped_burst.summary.mean.max(1e-9),
        BURST as f64 / piped_burst.summary.mean.max(1e-9),
        BURST as f64 / seq_burst.summary.mean.max(1e-9),
    ));
    report.note(format!(
        "transfer-bound (split {tb_split}, realtime clock): 3-stage is \
         {:.2}x the 2-stage throughput — the dedicated transfer stage \
         overlaps the wire with both compute stages",
        tb_two.summary.mean / tb_three.summary.mean.max(1e-9),
    ));
    report.note(format!(
        "transfer codec at {:.0} Mbps (split {cc_split}): fp16 {:.2}x, \
         int8 {:.2}x lower mean t_transfer than fp32; codec-aware plans: {}",
        net.low_mbps,
        codec_mean(TransferCodec::Fp32, net.low_mbps)
            / codec_mean(TransferCodec::Fp16, net.low_mbps).max(1e-12),
        codec_mean(TransferCodec::Fp32, net.low_mbps)
            / codec_mean(TransferCodec::Int8, net.low_mbps).max(1e-12),
        split_notes.join("; "),
    ));
    report.note(format!(
        "faulted link at {:.0} Mbps: 1% chunk loss costs {:.2}x the clean \
         mean transfer+backoff ({} chunks lost, {} redone attempts on the row)",
        net.high_mbps,
        xfer_lossy.summary.mean / xfer_clean.summary.mean.max(1e-12),
        fault_counters.chunks_lost,
        fault_counters.failed_transfers,
    ));
    assert!(switch.summary.p95 < 0.98e-3, "switch p95 must beat the paper's 0.98 ms");
    assert!(
        codec_mean(TransferCodec::Int8, net.low_mbps) * 2.0
            <= codec_mean(TransferCodec::Fp32, net.low_mbps),
        "int8 must at least halve mean t_transfer on the transfer-bound row at {} Mbps",
        net.low_mbps
    );
    report.print();
    neukonfig::bench::write_json_baseline("BENCH_hot_path.json", "hot_path", &all)?;
    println!("wrote BENCH_hot_path.json ({} rows)", all.len());
    let _ = Arc::strong_count(&env);
    Ok(())
}
