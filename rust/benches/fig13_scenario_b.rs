//! Fig 13: Dynamic Switching Scenario B downtime grid.
//! Paper: Case 1 (new container) ~1.9 s; Case 2 (same container) ~0.6 s.

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{measure_downtime, Approach, ExperimentSetup};
use neukonfig::coordinator::PlacementCase;
use neukonfig::metrics::Table;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env("mobilenetv2")?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let cfg = &setup.cfg;

    let mut report = Report::new("Fig 13: Dynamic Switching Scenario B downtime grid");
    let mut means = Vec::new();
    for (case, label, paper) in [
        (PlacementCase::NewContainer, "case 1 (new containers)", "~1.9 s"),
        (PlacementCase::SameContainer, "case 2 (same containers)", "~0.6 s"),
    ] {
        let mut case_samples = Vec::new();
        for (from, to, dir) in [
            (cfg.network.high_mbps, cfg.network.low_mbps, "to 5 Mbps"),
            (cfg.network.low_mbps, cfg.network.high_mbps, "to 20 Mbps"),
        ] {
            let mut t = Table::new(
                &format!("{label}, {dir} (paper: {paper})"),
                &["cpu %", "mem %", "downtime", "real", "simulated"],
            );
            for sp in common::grid() {
                eprintln!("B {label} cell cpu={:.2} mem={:.2} {dir}", sp.cpu_avail, sp.mem_avail);
                let d = measure_downtime(&env, &profile, Approach::ScenarioB(case), sp, from, to)?;
                if let Some(rec) = &d {
                    case_samples.push(rec.total.as_secs_f64());
                }
                let mut row = vec![
                    format!("{:.0}", sp.cpu_avail * 100.0),
                    format!("{:.0}", sp.mem_avail * 100.0),
                ];
                row.extend(common::cell_str(&d));
                t.row(row);
            }
            report.table(t);
        }
        let mean = case_samples.iter().sum::<f64>() / case_samples.len() as f64;
        means.push(mean);
    }
    report.note(format!(
        "mean downtime: case 1 = {:.2} s (paper ~1.9 s), case 2 = {:.2} s (paper ~0.6 s); \
         case1/case2 ratio {:.1}x (paper ~3.2x — container start dominates case 1)",
        means[0],
        means[1],
        means[0] / means[1]
    ));
    assert!(means[0] > means[1], "case 1 must cost more than case 2");
    report.print();
    Ok(())
}
