//! Figs 14/15: frame drop rate during the downtime window for different
//! incoming frame rates, at 20 Mbps (Fig 14) and 5 Mbps (Fig 15).
//! Paper: more frames dropped as the incoming rate increases; Dynamic
//! Switching keeps processing (some) frames, the baseline none.

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{
    frame_drop_rows, measure_downtime, Approach, ExperimentSetup,
};
use neukonfig::coordinator::PlacementCase;
use neukonfig::metrics::{fmt_duration, Table};
use neukonfig::stress::StressProfile;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env("mobilenetv2")?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let cfg = &setup.cfg;
    let fps_list = [5.0, 10.0, 15.0, 20.0, 25.0, 30.0, 60.0];

    let mut report = Report::new("Figs 14/15: frame drop rate during downtime");
    for (from, to, fig) in [
        (cfg.network.low_mbps, cfg.network.high_mbps, "Fig 14 (network now 20 Mbps)"),
        (cfg.network.high_mbps, cfg.network.low_mbps, "Fig 15 (network now 5 Mbps)"),
    ] {
        let mut t = Table::new(
            &format!("{fig}"),
            &["approach", "downtime", "fps", "arrivals", "served", "dropped", "rate"],
        );
        for approach in [
            Approach::ScenarioA(PlacementCase::SameContainer),
            Approach::ScenarioB(PlacementCase::NewContainer),
            Approach::ScenarioB(PlacementCase::SameContainer),
            Approach::PauseResume,
        ] {
            eprintln!("measuring downtime for {} ...", approach.label());
            let rec =
                measure_downtime(&env, &profile, approach, StressProfile::none(), from, to)?
                    .expect("fits at full availability");
            let mut last_drops = 0u64;
            for row in
                frame_drop_rows(&profile, cfg, approach, rec.total, from, to, &fps_list)
            {
                // Paper's trend: drops never decrease as fps rises.
                assert!(
                    row.outcome.dropped + 1 >= last_drops,
                    "drops must not fall as fps rises"
                );
                last_drops = row.outcome.dropped;
                t.row(vec![
                    row.approach.to_string(),
                    fmt_duration(Duration::from_secs_f64(row.downtime_s)),
                    format!("{:.0}", row.fps),
                    row.outcome.arrivals.to_string(),
                    row.outcome.served.to_string(),
                    row.outcome.dropped.to_string(),
                    format!("{:.2}", row.outcome.drop_rate()),
                ]);
            }
        }
        report.table(t);
    }
    report.note(
        "shape: drop count grows with incoming FPS; Dynamic Switching serves frames \
         during its (shorter) window while Pause-and-Resume serves none — matching \
         the paper's Figs 14/15 trends",
    );
    report.print();
    Ok(())
}
