//! Fig 11: Pause-and-Resume downtime across the CPU x memory availability
//! grid, both speed-change directions. Paper: ~6 s, insensitive to CPU and
//! memory availability; no results at <= 10 % memory.

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{measure_downtime, Approach, ExperimentSetup};
use neukonfig::metrics::Table;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env("mobilenetv2")?;
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let cfg = &setup.cfg;

    let mut report = Report::new("Fig 11: Pause-and-Resume downtime grid");
    let mut all_ok: Vec<f64> = Vec::new();
    for (from, to, dir) in [
        (cfg.network.high_mbps, cfg.network.low_mbps, "(a) to 5 Mbps"),
        (cfg.network.low_mbps, cfg.network.high_mbps, "(b) to 20 Mbps"),
    ] {
        let mut t = Table::new(
            &format!("{dir} (paper: ~6 s flat)"),
            &["cpu %", "mem %", "downtime", "real", "simulated"],
        );
        for sp in common::grid() {
            eprintln!("cell cpu={:.2} mem={:.2} {dir}", sp.cpu_avail, sp.mem_avail);
            let d = measure_downtime(&env, &profile, Approach::PauseResume, sp, from, to)?;
            if let Some(rec) = &d {
                all_ok.push(rec.total.as_secs_f64());
            } else {
                assert!(
                    sp.mem_avail <= 0.10 + 1e-9,
                    "OOM only expected at <=10% memory, got cpu={} mem={}",
                    sp.cpu_avail,
                    sp.mem_avail
                );
            }
            let mut row = vec![
                format!("{:.0}", sp.cpu_avail * 100.0),
                format!("{:.0}", sp.mem_avail * 100.0),
            ];
            row.extend(common::cell_str(&d));
            t.row(row);
        }
        report.table(t);
    }
    let min = all_ok.iter().cloned().fold(f64::MAX, f64::min);
    let max = all_ok.iter().cloned().fold(0.0f64, f64::max);
    report.note(format!(
        "downtime range across grid: {min:.2}-{max:.2} s (paper: ~6 s, flat). \
         Flatness ratio max/min = {:.2} (CPU/memory availability does not drive downtime)",
        max / min
    ));
    assert!(max > 1.0, "baseline downtime should be seconds");
    assert!(max / min < 3.0, "grid should be roughly flat");
    report.print();
    Ok(())
}
