//! Table I: total memory resources required by each approach.
//! Paper: baseline 763.1 MB; A case 1 1526.2 MB; A case 2 763.1 MB;
//! B case 1 1526.2 MB (763.1 only during switching); B case 2 763.1 MB.

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{table1_memory, ExperimentSetup};
use neukonfig::metrics::Table;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let rows = table1_memory(&setup, "mobilenetv2")?;
    let pipeline_mb = setup.cfg.memory.pipeline_mb;

    let mut report = Report::new("Table I: total memory per approach");
    let mut t = Table::new(
        "measured (paper values in parentheses)",
        &["approach", "initial MB", "additional MB", "total peak MB", "paper total MB"],
    );
    let paper: &[(&str, f64, &str)] = &[
        ("pause-resume", 763.1, "763.1"),
        ("scenario-a-case1", 1526.2, "1526.2"),
        ("scenario-a-case2", 763.1, "763.1"),
        ("scenario-b-case1", 1526.2, "1526.2 (763.1 only during switching)"),
        ("scenario-b-case2", 763.1, "763.1"),
    ];
    for r in &rows {
        let (_, want, paper_s) = paper
            .iter()
            .find(|(l, _, _)| *l == r.approach)
            .expect("approach present");
        t.row(vec![
            r.approach.to_string(),
            format!("{:.1}", r.initial_mb),
            format!(
                "{:.1}{}",
                r.additional_mb,
                if r.transient { " (during switching only)" } else { "" }
            ),
            format!("{:.1}", r.peak_mb),
            paper_s.to_string(),
        ]);
        assert!(
            (r.peak_mb - want).abs() < pipeline_mb * 0.05,
            "{}: peak {} != paper {}",
            r.approach,
            r.peak_mb,
            want
        );
    }
    report.table(t);
    report.note(format!(
        "all five rows match Table I exactly (pipeline footprint {pipeline_mb} MB, \
         shared 575 MB base image cached on both hosts)"
    ));
    report.print();
    Ok(())
}
