//! Fig 3: MobileNetV2 (block-granular) sweep. Paper: optimal split moves
//! from L2 @ 20 Mbps to L35 @ 5 Mbps (blocks; deeper on slower network).

mod common;

use neukonfig::bench::Report;
use neukonfig::coordinator::experiments::{partition_sweep, ExperimentSetup};
use neukonfig::metrics::Table;

fn main() -> anyhow::Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env("mobilenetv2")?;
    eprintln!(
        "profiling mobilenetv2 ({} block units, real execution)...",
        env.manifest.num_layers()
    );
    let profile = setup.measured_profile(&env, if common::quick() { 2 } else { 5 })?;

    let mut report = Report::new("Fig 3: MobileNetV2 partition sweep (blocks)");
    let mut optima = Vec::new();
    for bw in [setup.cfg.network.high_mbps, setup.cfg.network.low_mbps] {
        let rows = partition_sweep(&profile, bw, setup.cfg.network.latency);
        let opt = rows.iter().find(|r| r.optimal).unwrap().clone();
        let mut t = Table::new(
            &format!("@ {bw} Mbps — optimal split {} ({})", opt.split, opt.layer),
            &["split", "after block", "edge ms", "xfer ms", "cloud ms", "total ms", "out KB"],
        );
        for r in &rows {
            t.row(vec![
                format!("{}{}", r.split, if r.optimal { "*" } else { "" }),
                r.layer.clone(),
                format!("{:.1}", r.edge_s * 1e3),
                format!("{:.1}", r.transfer_s * 1e3),
                format!("{:.1}", r.cloud_s * 1e3),
                format!("{:.1}", r.total_s * 1e3),
                format!("{:.1}", r.out_kb),
            ]);
        }
        report.table(t);
        optima.push(opt.split);
    }
    report.note(format!(
        "measured optimal block split: {} @ 20 Mbps -> {} @ 5 Mbps \
         (paper: block 2 -> block 35; same direction)",
        optima[0], optima[1]
    ));
    assert!(
        optima[1] >= optima[0],
        "SHAPE CHECK FAILED: split should move deeper at lower bandwidth"
    );
    report.print();
    Ok(())
}
