#![allow(dead_code)] // shared across bench binaries; not all use every helper

//! Shared helpers for the per-figure bench binaries.

use neukonfig::stress::StressProfile;

/// Grid resolution control: full paper grid (20 cells) by default;
/// `NEUKONFIG_BENCH_QUICK=1` reduces to the 4 corners + centre.
pub fn grid() -> Vec<StressProfile> {
    if quick() {
        vec![
            StressProfile::new(0.25, 0.10),
            StressProfile::new(0.25, 1.0),
            StressProfile::new(1.0, 0.10),
            StressProfile::new(1.0, 1.0),
            StressProfile::new(0.5, 0.5),
        ]
    } else {
        StressProfile::paper_grid()
    }
}

pub fn quick() -> bool {
    std::env::var("NEUKONFIG_BENCH_QUICK").as_deref() == Ok("1")
}

/// Render a downtime cell for a report row.
pub fn cell_str(d: &Option<neukonfig::metrics::DowntimeRecord>) -> Vec<String> {
    match d {
        Some(d) => vec![
            neukonfig::metrics::fmt_duration(d.total),
            neukonfig::metrics::fmt_duration(d.real()),
            neukonfig::metrics::fmt_duration(d.simulated),
        ],
        None => vec!["no result (OOM)".into(), "-".into(), "-".into()],
    }
}
