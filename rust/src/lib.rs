//! # NEUKONFIG
//!
//! Reproduction of *"NEUKONFIG: Reducing Edge Service Downtime When
//! Repartitioning DNNs"* (Majeed, Kilpatrick, Spence, Varghese — IEEE IC2E
//! 2021) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build-time Python)** — VGG-19 and MobileNetV2 defined layer-
//!   by-layer in JAX over Pallas kernels, AOT-lowered to one HLO module per
//!   partition unit (`python/compile/`).
//! * **L3 (this crate)** — the NEUKONFIG coordinator: edge-cloud pipelines,
//!   the Pause-and-Resume baseline, the Dynamic Switching approaches
//!   (Scenario A/B × Case 1/2), request routing, the repartition planner,
//!   and every substrate the paper's testbed provided (network emulation,
//!   container lifecycle, stress control, metrics).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts via the PJRT C API and executes them natively.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod bench;
pub mod clock;
pub mod codec;
pub mod config;
pub mod container;
pub mod coordinator;
pub mod device;
pub mod lint;
pub mod metrics;
pub mod models;
pub mod netsim;
pub mod profiler;
pub mod runtime;
pub mod stress;
pub mod util;

pub use clock::Clock;
pub use config::ExperimentConfig;
