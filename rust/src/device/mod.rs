//! Device model: the video camera (a Raspberry Pi 3B+ in the paper)
//! streaming frames to the edge server.
//!
//! Frames are synthetic but deterministic: a per-frame gradient pattern
//! plus seeded noise, normalised like camera RGB input. The source is a
//! pull-based generator so both the simulated sweeps (frame timestamps on
//! the virtual timeline) and the realtime serving example (a thread pacing
//! `next()` at the configured FPS) share one implementation.

use std::time::Duration;

use crate::util::prng::Prng;

/// One captured video frame.
#[derive(Debug, Clone)]
pub struct Frame {
    pub id: u64,
    /// Capture timestamp on the experiment timeline.
    pub captured_at: Duration,
    /// NHWC f32 pixels in [0, 1].
    pub pixels: Vec<f32>,
    pub shape: Vec<usize>,
}

/// Deterministic synthetic camera.
pub struct FrameSource {
    shape: Vec<usize>,
    fps: f64,
    seed: u64,
    next_id: u64,
}

impl FrameSource {
    /// `shape` is the model input shape (e.g. `[1, 64, 64, 3]`).
    pub fn new(shape: &[usize], fps: f64, seed: u64) -> Self {
        assert!(fps > 0.0, "fps must be positive");
        assert_eq!(shape.len(), 4, "expected NHWC shape");
        FrameSource { shape: shape.to_vec(), fps, seed, next_id: 0 }
    }

    pub fn fps(&self) -> f64 {
        self.fps
    }

    /// Capture interval between consecutive frames.
    pub fn interval(&self) -> Duration {
        Duration::from_secs_f64(1.0 / self.fps)
    }

    /// Timestamp at which frame `id` is captured.
    pub fn capture_time(&self, id: u64) -> Duration {
        Duration::from_secs_f64(id as f64 / self.fps)
    }

    /// Generate the next frame (deterministic in `(seed, id)`).
    pub fn next_frame(&mut self) -> Frame {
        let id = self.next_id;
        self.next_id += 1;
        self.frame(id)
    }

    /// Generate frame `id` without advancing the stream.
    pub fn frame(&self, id: u64) -> Frame {
        let (_, h, w, c) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let mut rng = Prng::new(self.seed ^ (id.wrapping_mul(0x9E37_79B9)));
        let mut pixels = Vec::with_capacity(h * w * c);
        // Moving diagonal gradient (scene motion) + per-pixel sensor noise.
        let phase = (id % 97) as f32 / 97.0;
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    let g = ((x + y) as f32 / (h + w) as f32 + phase + ch as f32 * 0.1) % 1.0;
                    let noise = rng.next_f32_range(-0.05, 0.05);
                    pixels.push((g + noise).clamp(0.0, 1.0));
                }
            }
        }
        Frame { id, captured_at: self.capture_time(id), pixels, shape: self.shape.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src() -> FrameSource {
        FrameSource::new(&[1, 8, 8, 3], 15.0, 42)
    }

    #[test]
    fn frame_sized_to_shape() {
        let f = src().frame(0);
        assert_eq!(f.pixels.len(), 8 * 8 * 3);
        assert_eq!(f.shape, vec![1, 8, 8, 3]);
    }

    #[test]
    fn deterministic_per_id() {
        let a = src().frame(5);
        let b = src().frame(5);
        assert_eq!(a.pixels, b.pixels);
    }

    #[test]
    fn frames_differ() {
        let s = src();
        assert_ne!(s.frame(1).pixels, s.frame(2).pixels);
    }

    #[test]
    fn pixels_in_unit_range() {
        for p in src().frame(3).pixels {
            assert!((0.0..=1.0).contains(&p));
        }
    }

    #[test]
    fn capture_times_paced_by_fps() {
        let s = src();
        assert_eq!(s.capture_time(0), Duration::ZERO);
        let dt = s.capture_time(15) - s.capture_time(0);
        assert!((dt.as_secs_f64() - 1.0).abs() < 1e-9);
        // Duration has nanosecond resolution; allow that rounding.
        assert!((s.interval().as_secs_f64() - 1.0 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn next_frame_advances() {
        let mut s = src();
        assert_eq!(s.next_frame().id, 0);
        assert_eq!(s.next_frame().id, 1);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_fps() {
        FrameSource::new(&[1, 8, 8, 3], 0.0, 0);
    }
}
