//! Experiment configuration.
//!
//! Defaults mirror the paper's testbed (§IV-A): 20 Mbps fibre-broadband
//! uplink dropping to 5 Mbps, 20 ms RTT, a 4-core/8 GB edge and an
//! 8-core/16 GB cloud, Docker 18.09 container costs, and the measured
//! 763.1 MB per-pipeline memory footprint of Table I.
//!
//! The Docker control-plane costs have no real counterpart in this repo
//! (we do the *model-load* work for real via PJRT compilation, but not
//! `docker pause`/image start); they are injected as simulated clock
//! offsets and are individually zeroable (`--no-sim-container-costs`) so
//! every reported downtime can be decomposed into real + simulated parts.

use std::time::Duration;

/// Container-control-plane cost model (simulated offsets; paper §IV).
#[derive(Debug, Clone)]
pub struct ContainerCosts {
    /// `docker pause` of a running container.
    pub pause: Duration,
    /// `docker unpause`.
    pub unpause: Duration,
    /// Cold start of the optimised 575 MB image (Scenario B Case 1).
    pub container_start: Duration,
    /// Stop/remove of a drained container.
    pub container_stop: Duration,
    /// TF/Keras application bring-up inside a container that our PJRT
    /// compile path does not exhibit (graph/session construction). Applied
    /// once per pipeline initialisation.
    pub app_bringup: Duration,
    /// Extra teardown+reload the naive Pause-and-Resume application does on
    /// top of `app_bringup` (full TensorFlow model reload on BOTH sides
    /// while the containers are frozen).
    pub baseline_reload: Duration,
}

impl Default for ContainerCosts {
    fn default() -> Self {
        ContainerCosts {
            pause: Duration::from_millis(300),
            unpause: Duration::from_millis(300),
            container_start: Duration::from_millis(600),
            container_stop: Duration::from_millis(200),
            app_bringup: Duration::from_millis(450),
            baseline_reload: Duration::from_millis(1000),
        }
    }
}

impl ContainerCosts {
    /// All-zero costs: report only the real measured work.
    pub fn zero() -> Self {
        ContainerCosts {
            pause: Duration::ZERO,
            unpause: Duration::ZERO,
            container_start: Duration::ZERO,
            container_stop: Duration::ZERO,
            app_bringup: Duration::ZERO,
            baseline_reload: Duration::ZERO,
        }
    }
}

/// Memory model (Table I).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Total edge-server memory (paper: 8 GB).
    pub edge_total_mb: f64,
    /// Total cloud-server memory (paper: 16 GB).
    pub cloud_total_mb: f64,
    /// Measured per-pipeline footprint (Table I "Initial Resources").
    pub pipeline_mb: f64,
    /// Optimised container image size (paper §IV-B), shared between
    /// pipelines via the local cache.
    pub image_mb: f64,
    /// OS + daemon overhead reserved on every host. With this reservation,
    /// a 763.1 MB pipeline no longer fits at 10 % memory availability on
    /// the 8 GB edge — reproducing the paper's empty Fig-11 cells.
    pub os_overhead_mb: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel {
            edge_total_mb: 8192.0,
            cloud_total_mb: 16384.0,
            pipeline_mb: 763.1,
            image_mb: 575.0,
            os_overhead_mb: 256.0,
        }
    }
}

/// Network conditions (§IV-A).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// "Typical broadband upload" speed.
    pub high_mbps: f64,
    /// "Poorer quality upload" speed.
    pub low_mbps: f64,
    /// One-way latency between edge and cloud.
    pub latency: Duration,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            high_mbps: 20.0,
            low_mbps: 5.0,
            latency: Duration::from_millis(20),
        }
    }
}

/// Compute model: relative speeds of the two domains.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Edge speed factor (1.0 = this host).
    pub edge_scale: f64,
    /// Cloud speed factor (paper: 8 cores vs 4 -> ~2x).
    pub cloud_scale: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel { edge_scale: 1.0, cloud_scale: 2.0 }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, Default)]
pub struct ExperimentConfig {
    pub costs: ContainerCosts,
    pub memory: MemoryModel,
    pub network: NetworkModel,
    pub compute: ComputeModel,
    /// Edge frame-queue capacity (frames waiting for the edge stage).
    pub queue_capacity: usize,
    pub seed: u64,
    /// Retry discipline for faultable uplink transfers. The default reads
    /// the `NEUKONFIG_RETRY_*` env knobs; inert unless a fault plan is
    /// installed on the link (`NEUKONFIG_FAULT_PROFILE`).
    pub retry: crate::netsim::RetryPolicy,
}

impl ExperimentConfig {
    pub fn new() -> Self {
        ExperimentConfig { queue_capacity: 8, seed: 0, ..Default::default() }
    }

    /// Zero out the simulated Docker costs.
    pub fn without_sim_costs(mut self) -> Self {
        self.costs = ContainerCosts::zero();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::new();
        assert_eq!(c.network.high_mbps, 20.0);
        assert_eq!(c.network.low_mbps, 5.0);
        assert_eq!(c.network.latency, Duration::from_millis(20));
        assert_eq!(c.memory.pipeline_mb, 763.1);
        assert_eq!(c.memory.image_mb, 575.0);
        assert_eq!(c.memory.edge_total_mb, 8192.0);
    }

    #[test]
    fn zero_costs() {
        let z = ContainerCosts::zero();
        assert_eq!(z.pause, Duration::ZERO);
        assert_eq!(z.baseline_reload, Duration::ZERO);
    }

    #[test]
    fn retry_policy_is_wired_in() {
        let c = ExperimentConfig::new();
        // The env-driven default can be overridden, but must always allow
        // at least one attempt or every faultable transfer would abort.
        assert!(c.retry.max_attempts >= 1);
    }

    #[test]
    fn without_sim_costs_keeps_rest() {
        let c = ExperimentConfig::new().without_sim_costs();
        assert_eq!(c.costs.container_start, Duration::ZERO);
        assert_eq!(c.network.high_mbps, 20.0);
        assert_eq!(c.queue_capacity, 8);
    }
}
