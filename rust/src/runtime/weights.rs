//! Weight store: loads `weights.bin` and materialises per-layer parameter
//! literals for the PJRT executables.
//!
//! Weights are runtime inputs (not HLO constants) — uploading them is part
//! of the pipeline-initialisation cost the paper measures as part of
//! container/model startup, and it keeps the HLO text artifacts small.

use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient};

use crate::models::{LayerManifest, ModelManifest};

/// The raw weight blob, shared between pipelines (read-only).
#[derive(Clone)]
pub struct WeightStore {
    blob: Arc<Vec<u8>>,
}

impl WeightStore {
    /// Read `<model dir>/weights.bin` and validate its size.
    pub fn load(manifest: &ModelManifest) -> Result<Self> {
        let path = manifest.weights_path();
        let blob = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if blob.len() != manifest.weights_bytes {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                manifest.weights_bytes
            );
        }
        Ok(WeightStore { blob: Arc::new(blob) })
    }

    /// In-memory store (tests).
    pub fn from_bytes(blob: Vec<u8>) -> Self {
        WeightStore { blob: Arc::new(blob) }
    }

    pub fn len(&self) -> usize {
        self.blob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blob.is_empty()
    }

    /// Raw f32 slice for one parameter (zero-copy view of the blob).
    pub fn param_bytes(&self, p: &crate::models::ParamEntry) -> Result<&[u8]> {
        let end = p.offset_bytes + p.size_bytes;
        if end > self.blob.len() {
            bail!("param {} [{}..{end}) outside weights.bin", p.name, p.offset_bytes);
        }
        Ok(&self.blob[p.offset_bytes..end])
    }

    /// Stage one layer's parameters as device buffers, in declaration
    /// order — exactly the positional arguments `unit(x, *params)` expects
    /// after x. This is the real "model load" data movement.
    pub fn layer_buffers(
        &self,
        client: &PjRtClient,
        layer: &LayerManifest,
    ) -> Result<Vec<PjRtBuffer>> {
        layer
            .params
            .iter()
            .map(|p| {
                let bytes = self.param_bytes(p)?;
                // NOTE: not `buffer_from_host_raw_bytes` — xla 0.1.6 passes
                // its ElementType discriminant where PJRT expects a
                // PrimitiveType, corrupting the dtype. Decode to f32 (also
                // fixes the blob's 1-byte alignment) and use the typed API.
                let floats: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                client
                    .buffer_from_host_buffer::<f32>(&floats, &p.shape, None)
                    .map_err(|e| anyhow::anyhow!("buffer for {}: {e:?}", p.name))
            })
            .collect()
    }

    /// Build the parameter literals for one layer (host-side view; used by
    /// tests and tooling).
    pub fn layer_literals(&self, layer: &LayerManifest) -> Result<Vec<Literal>> {
        layer
            .params
            .iter()
            .map(|p| {
                let bytes = self.param_bytes(p)?;
                let expected: usize = p.shape.iter().product::<usize>() * 4;
                if bytes.len() != expected {
                    bail!(
                        "param {}: {} bytes but shape {:?} needs {expected}",
                        p.name,
                        bytes.len(),
                        p.shape
                    );
                }
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32,
                    &p.shape,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal for {}: {e:?}", p.name))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ParamEntry;

    fn entry(offset: usize, shape: &[usize]) -> ParamEntry {
        ParamEntry {
            name: "w".into(),
            shape: shape.to_vec(),
            offset_bytes: offset,
            size_bytes: shape.iter().product::<usize>() * 4,
        }
    }

    #[test]
    fn slices_params() {
        let data: Vec<u8> = (0..32).collect();
        let ws = WeightStore::from_bytes(data);
        let p = entry(4, &[2, 3]);
        let got = ws.param_bytes(&p).unwrap();
        assert_eq!(got.len(), 24);
        assert_eq!(got[0], 4);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let ws = WeightStore::from_bytes(vec![0; 8]);
        assert!(ws.param_bytes(&entry(4, &[2])).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let vals: Vec<f32> = vec![1.5, -2.0, 3.25, 0.0, 7.0, -8.5];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let ws = WeightStore::from_bytes(bytes);
        let layer = LayerManifest {
            index: 0,
            name: "l".into(),
            kind: "conv".into(),
            hlo: "x".into(),
            input_shape: vec![1],
            output_shape: vec![1],
            output_bytes: 4,
            flops: 0,
            params: vec![entry(0, &[2, 3])],
        };
        let lits = ws.layer_literals(&layer).unwrap();
        assert_eq!(lits.len(), 1);
        assert_eq!(lits[0].to_vec::<f32>().unwrap(), vals);
    }
}
