//! Weight store: loads `weights.bin` and materialises per-layer parameter
//! literals for the PJRT executables.
//!
//! Weights are runtime inputs (not HLO constants) — uploading them is part
//! of the pipeline-initialisation cost the paper measures as part of
//! container/model startup, and it keeps the HLO text artifacts small.
//!
//! The blob is decoded from little-endian bytes to f32 exactly once per
//! store (lazily, shared across clones); every staging call after that is
//! a zero-copy slice view, so repartition bring-up never re-decodes.

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient};

use crate::models::{LayerManifest, ModelManifest};

/// The raw weight blob, shared between pipelines (read-only).
#[derive(Clone)]
pub struct WeightStore {
    blob: Arc<Vec<u8>>,
    /// Decode-once f32 view of the blob (also fixes its 1-byte alignment).
    /// Lazily filled on first staging; clones share the decoded copy.
    floats: Arc<OnceLock<Vec<f32>>>,
}

impl WeightStore {
    /// Read `<model dir>/weights.bin` and validate its size.
    pub fn load(manifest: &ModelManifest) -> Result<Self> {
        let path = manifest.weights_path();
        let blob = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if blob.len() != manifest.weights_bytes {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                manifest.weights_bytes
            );
        }
        Ok(Self::from_bytes(blob))
    }

    /// In-memory store (tests).
    pub fn from_bytes(blob: Vec<u8>) -> Self {
        WeightStore {
            blob: Arc::new(blob),
            floats: Arc::new(OnceLock::new()),
        }
    }

    pub fn len(&self) -> usize {
        self.blob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blob.is_empty()
    }

    /// Raw f32 slice for one parameter (zero-copy view of the blob).
    pub fn param_bytes(&self, p: &crate::models::ParamEntry) -> Result<&[u8]> {
        let end = p.offset_bytes + p.size_bytes;
        if end > self.blob.len() {
            bail!("param {} [{}..{end}) outside weights.bin", p.name, p.offset_bytes);
        }
        Ok(&self.blob[p.offset_bytes..end])
    }

    /// The whole blob as f32, decoded at most once per store (trailing
    /// bytes that do not fill a full f32 are ignored).
    pub fn as_f32(&self) -> &[f32] {
        self.floats.get_or_init(|| {
            self.blob
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        })
    }

    /// Zero-copy f32 view of one parameter. Offsets in the manifest are
    /// sums of f32 tensor sizes, so they are always 4-byte multiples; a
    /// misaligned entry is a packer bug and is rejected.
    pub fn param_f32(&self, p: &crate::models::ParamEntry) -> Result<&[f32]> {
        if p.offset_bytes % 4 != 0 || p.size_bytes % 4 != 0 {
            bail!(
                "param {} [{}; {} bytes) is not f32-aligned",
                p.name,
                p.offset_bytes,
                p.size_bytes
            );
        }
        let start = p.offset_bytes / 4;
        let end = start + p.size_bytes / 4;
        let floats = self.as_f32();
        if end > floats.len() {
            bail!("param {} [{}..{}) outside weights.bin", p.name, p.offset_bytes, end * 4);
        }
        Ok(&floats[start..end])
    }

    /// Stage one layer's parameters as device buffers, in declaration
    /// order — exactly the positional arguments `unit(x, *params)` expects
    /// after x. This is the real "model load" data movement. (Callers on
    /// the repartition path go through `Domain::layer_weight_buffers`,
    /// which caches the result per domain.)
    pub fn layer_buffers(
        &self,
        client: &PjRtClient,
        layer: &LayerManifest,
    ) -> Result<Vec<PjRtBuffer>> {
        layer
            .params
            .iter()
            .map(|p| {
                // NOTE: not `buffer_from_host_raw_bytes` — xla 0.1.6 passes
                // its ElementType discriminant where PJRT expects a
                // PrimitiveType, corrupting the dtype. The decode-once f32
                // view (also fixes the blob's 1-byte alignment) feeds the
                // typed API without per-call decoding.
                let floats = self.param_f32(p)?;
                client
                    .buffer_from_host_buffer::<f32>(floats, &p.shape, None)
                    .map_err(|e| anyhow::anyhow!("buffer for {}: {e:?}", p.name))
            })
            .collect()
    }

    /// Bytes one layer occupies once staged as device buffers (the unit of
    /// account for the per-domain weight-cache byte budget). Validates the
    /// parameter slices against the blob so a layer that could never stage
    /// is also rejected here, keeping cache accounting and staging in
    /// agreement.
    pub fn layer_staged_bytes(&self, layer: &LayerManifest) -> Result<usize> {
        let mut total = 0usize;
        for p in &layer.params {
            self.param_bytes(p)?;
            total += p.size_bytes;
        }
        Ok(total)
    }

    /// Build the parameter literals for one layer (host-side view; used by
    /// tests and tooling).
    pub fn layer_literals(&self, layer: &LayerManifest) -> Result<Vec<Literal>> {
        layer
            .params
            .iter()
            .map(|p| {
                let bytes = self.param_bytes(p)?;
                let expected: usize = p.shape.iter().product::<usize>() * 4;
                if bytes.len() != expected {
                    bail!(
                        "param {}: {} bytes but shape {:?} needs {expected}",
                        p.name,
                        bytes.len(),
                        p.shape
                    );
                }
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32,
                    &p.shape,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal for {}: {e:?}", p.name))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ParamEntry;

    fn entry(offset: usize, shape: &[usize]) -> ParamEntry {
        ParamEntry {
            name: "w".into(),
            shape: shape.to_vec(),
            offset_bytes: offset,
            size_bytes: shape.iter().product::<usize>() * 4,
        }
    }

    #[test]
    fn slices_params() {
        let data: Vec<u8> = (0..32).collect();
        let ws = WeightStore::from_bytes(data);
        let p = entry(4, &[2, 3]);
        let got = ws.param_bytes(&p).unwrap();
        assert_eq!(got.len(), 24);
        assert_eq!(got[0], 4);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let ws = WeightStore::from_bytes(vec![0; 8]);
        assert!(ws.param_bytes(&entry(4, &[2])).is_err());
        assert!(ws.param_f32(&entry(4, &[2])).is_err());
    }

    #[test]
    fn f32_view_matches_bytes() {
        let vals: Vec<f32> = vec![1.5, -2.0, 3.25, 0.0, 7.0, -8.5];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let ws = WeightStore::from_bytes(bytes);
        assert_eq!(ws.as_f32(), &vals[..]);
        let p = entry(8, &[2, 2]);
        assert_eq!(ws.param_f32(&p).unwrap(), &vals[2..6]);
    }

    #[test]
    fn decode_is_shared_across_clones() {
        let ws = WeightStore::from_bytes(vec![0u8; 16]);
        let view = ws.as_f32().as_ptr();
        let clone = ws.clone();
        // The clone must see the same decoded allocation, not re-decode.
        assert_eq!(clone.as_f32().as_ptr(), view);
    }

    #[test]
    fn rejects_misaligned_param() {
        let ws = WeightStore::from_bytes(vec![0u8; 16]);
        let mut p = entry(0, &[2]);
        p.offset_bytes = 2; // not a multiple of 4
        assert!(ws.param_f32(&p).is_err());
    }

    #[test]
    fn staged_bytes_sums_and_validates() {
        let ws = WeightStore::from_bytes(vec![0u8; 64]);
        let layer = LayerManifest {
            index: 0,
            name: "l".into(),
            kind: "conv".into(),
            hlo: "x".into(),
            input_shape: vec![1],
            output_shape: vec![1],
            output_bytes: 4,
            flops: 0,
            params: vec![entry(0, &[2, 3]), entry(24, &[4])],
        };
        assert_eq!(ws.layer_staged_bytes(&layer).unwrap(), 40);
        let mut bad = layer.clone();
        bad.params.push(entry(60, &[8])); // runs past the blob
        assert!(ws.layer_staged_bytes(&bad).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let vals: Vec<f32> = vec![1.5, -2.0, 3.25, 0.0, 7.0, -8.5];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let ws = WeightStore::from_bytes(bytes);
        let layer = LayerManifest {
            index: 0,
            name: "l".into(),
            kind: "conv".into(),
            hlo: "x".into(),
            input_shape: vec![1],
            output_shape: vec![1],
            output_bytes: 4,
            flops: 0,
            params: vec![entry(0, &[2, 3])],
        };
        let lits = ws.layer_literals(&layer).unwrap();
        assert_eq!(lits.len(), 1);
        assert_eq!(lits[0].to_vec::<f32>().unwrap(), vals);
    }
}
