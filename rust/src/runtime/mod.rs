//! Runtime bridge: loads the AOT HLO artifacts and executes them on the
//! PJRT CPU client from the Rust hot path (Python never runs at request
//! time).
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `PjRtClient::compile` -> `execute`.
//! HLO *text* is the interchange format (jax >= 0.5 protos are rejected by
//! the bundled xla_extension 0.5.1).

pub mod weights;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::clock::Clock;
use crate::models::{LayerManifest, ModelManifest};
pub use weights::WeightStore;

/// An execution domain — the "edge server" or the "cloud server".
///
/// Each domain owns a PJRT CPU client (its "machine"). `cpu_scale` models
/// relative compute speed and CPU availability: measured execution time is
/// dilated by `1/cpu_scale` on the experiment clock (the stress-ng analogue;
/// DESIGN.md §Substitutions).
pub struct Domain {
    pub name: String,
    client: PjRtClient,
    /// Relative CPU speed (1.0 = this host's full speed), stored as f64
    /// bits so the stress controller can adjust it at runtime. The paper's
    /// cloud (8 cores) vs edge (4 cores) is modelled as cloud 2.0 vs edge
    /// 1.0; stress-ng CPU availability multiplies on top.
    cpu_scale_bits: std::sync::atomic::AtomicU64,
    /// Compiled-executable cache keyed by HLO path. Per-layer artifacts
    /// mean a *repartition* never introduces a new module on a domain that
    /// has already run that layer — Dynamic Switching exploits this (the
    /// proactive design of SIII-B); the naive Pause-and-Resume baseline
    /// reloads everything uncached, like the Keras app in the paper.
    exe_cache: Mutex<HashMap<PathBuf, Arc<PjRtLoadedExecutable>>>,
}

impl Domain {
    pub fn new(name: impl Into<String>, cpu_scale: f64) -> Result<Arc<Self>> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Arc::new(Domain {
            name: name.into(),
            client,
            cpu_scale_bits: std::sync::atomic::AtomicU64::new(cpu_scale.to_bits()),
            exe_cache: Mutex::new(HashMap::new()),
        }))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn cpu_scale(&self) -> f64 {
        f64::from_bits(self.cpu_scale_bits.load(std::sync::atomic::Ordering::Relaxed))
    }

    /// Adjust the effective CPU speed (stress-ng analogue).
    pub fn set_cpu_scale(&self, scale: f64) {
        assert!(scale > 0.0, "cpu scale must be positive");
        self.cpu_scale_bits
            .store(scale.to_bits(), std::sync::atomic::Ordering::Relaxed);
    }

    /// Load + compile an HLO module, with optional caching.
    pub fn compile_hlo(&self, path: &Path, use_cache: bool) -> Result<Arc<PjRtLoadedExecutable>> {
        if use_cache {
            if let Some(exe) = self.exe_cache.lock().unwrap().get(path) {
                return Ok(exe.clone());
            }
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?,
        );
        if use_cache {
            self.exe_cache
                .lock()
                .unwrap()
                .insert(path.to_path_buf(), exe.clone());
        }
        Ok(exe)
    }

    pub fn cache_len(&self) -> usize {
        self.exe_cache.lock().unwrap().len()
    }

    pub fn clear_cache(&self) {
        self.exe_cache.lock().unwrap().clear();
    }
}

/// f32 literal from a host slice (frame upload helper).
pub fn literal_from_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if expected != data.len() {
        anyhow::bail!("literal shape {shape:?} needs {expected} floats, got {}", data.len());
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("creating literal: {e:?}"))
}

/// Cost breakdown of building a chain (the "model load" part of pipeline
/// initialisation the paper's downtime windows contain).
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    pub compile: Duration,
    pub weights_upload: Duration,
    pub num_layers: usize,
}

/// One compiled partition unit, ready to execute.
///
/// Parameters are staged as device buffers once at build time; per-frame
/// execution chains device buffers between layers and reads back to the
/// host only at the chain boundary (EXPERIMENTS.md §Perf).
pub struct LayerExec {
    pub manifest: LayerManifest,
    exe: Arc<PjRtLoadedExecutable>,
    param_bufs: Vec<PjRtBuffer>,
}

impl LayerExec {
    /// Execute on a device buffer, returning the output device buffer
    /// (no host readback) — the hot-path form.
    pub fn run_buf(&self, input: &PjRtBuffer) -> Result<PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(1 + self.param_bufs.len());
        args.push(input);
        args.extend(self.param_bufs.iter());
        let mut out = self
            .exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.manifest.name))?;
        Ok(out.remove(0).remove(0))
    }

    /// Literal-in/literal-out execution with a full host round trip — used
    /// by the per-layer profiler where each layer is timed in isolation.
    pub fn run(&self, input: &Literal) -> Result<Literal> {
        let client = self.exe.client();
        let in_buf = client
            .buffer_from_host_literal(None, input)
            .map_err(|e| anyhow!("upload {}: {e:?}", self.manifest.name))?;
        let out = self.run_buf(&in_buf)?;
        out.to_literal_sync()
            .map_err(|e| anyhow!("readback {}: {e:?}", self.manifest.name))
    }
}

/// Per-run timing of a chain execution.
#[derive(Debug, Clone, Default)]
pub struct ChainTiming {
    /// Total execution time on the experiment clock (dilated by cpu_scale).
    pub total: Duration,
    /// Per-layer dilated times, aligned with the chain's layer range.
    pub per_layer: Vec<Duration>,
}

/// A compiled chain of consecutive partition units on one domain — one side
/// (edge or cloud) of an edge-cloud pipeline.
pub struct ChainExecutor {
    pub domain: Arc<Domain>,
    pub range: std::ops::Range<usize>,
    layers: Vec<LayerExec>,
    pub build_stats: BuildStats,
}

impl ChainExecutor {
    /// Compile units `range` of `manifest` on `domain` and stage their
    /// weights. This is real measured work — the heart of every pipeline
    /// initialisation cost in the paper's downtime equations.
    pub fn build(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
    ) -> Result<Self> {
        Self::build_opts(domain, manifest, range, weights, true)
    }

    /// [`Self::build`] without the executable cache — models a naive
    /// application that reloads the model from scratch (the Pause-and-
    /// Resume baseline).
    pub fn build_uncached(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
    ) -> Result<Self> {
        Self::build_opts(domain, manifest, range, weights, false)
    }

    pub fn build_opts(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
        use_cache: bool,
    ) -> Result<Self> {
        anyhow::ensure!(range.end <= manifest.num_layers(), "range out of bounds");
        let mut layers = Vec::with_capacity(range.len());
        let mut compile = Duration::ZERO;
        let mut upload = Duration::ZERO;
        for i in range.clone() {
            let lm = &manifest.layers[i];
            let t0 = Instant::now();
            let exe = domain.compile_hlo(&manifest.hlo_path(i), use_cache)?;
            compile += t0.elapsed();

            let t1 = Instant::now();
            let param_bufs = weights
                .layer_buffers(domain.client(), lm)
                .with_context(|| format!("weights for {}", lm.name))?;
            upload += t1.elapsed();

            layers.push(LayerExec { manifest: lm.clone(), exe, param_bufs });
        }
        Ok(ChainExecutor {
            domain,
            range: range.clone(),
            build_stats: BuildStats {
                compile,
                weights_upload: upload,
                num_layers: range.len(),
            },
            layers,
        })
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Execute the chain, chaining device buffers between layers (one
    /// upload, one readback). Real wall time is measured end-to-end; the
    /// difference implied by `cpu_scale` is injected on `clock` so stressed
    /// or slower domains take proportionally longer on the timeline.
    pub fn run(&self, input: &Literal, clock: &Clock) -> Result<(Literal, ChainTiming)> {
        let t0 = Instant::now();
        let out = self.run_raw(input)?;
        let real = t0.elapsed();
        let scale = self.domain.cpu_scale().max(1e-3);
        let dilated = real.mul_f64(1.0 / scale);
        if dilated > real {
            clock.advance(dilated - real);
        }
        Ok((out, ChainTiming { total: dilated, per_layer: Vec::new() }))
    }

    /// Execute without timing dilation (profiling / warmup).
    pub fn run_raw(&self, input: &Literal) -> Result<Literal> {
        if self.layers.is_empty() {
            return Ok(clone_literal(input));
        }
        let client = self.domain.client();
        let mut buf = client
            .buffer_from_host_literal(None, input)
            .map_err(|e| anyhow!("chain input upload: {e:?}"))?;
        for layer in &self.layers {
            buf = layer.run_buf(&buf)?;
        }
        buf.to_literal_sync()
            .map_err(|e| anyhow!("chain readback: {e:?}"))
    }

    pub fn layer(&self, i: usize) -> &LayerExec {
        &self.layers[i]
    }
}

/// Build a single-module executor for a fused partition artifact
/// (ablation counterpart of the per-layer chain; see
/// rust/benches/ablation_fused.rs). `side` selects edge (units [0, split))
/// or cloud (units [split, N)); parameters are the concatenation of the
/// covered units' parameters in declaration order.
pub fn build_fused_exec(
    domain: Arc<Domain>,
    manifest: &ModelManifest,
    entry: &crate::models::FusedEntry,
    edge_side: bool,
    weights: &WeightStore,
) -> Result<LayerExec> {
    let hlo = if edge_side { &entry.edge_hlo } else { &entry.cloud_hlo };
    let hlo = hlo
        .as_ref()
        .ok_or_else(|| anyhow!("fused entry at split {} has no such side", entry.split))?;
    let range = if edge_side {
        0..entry.split
    } else {
        entry.split..manifest.num_layers()
    };
    let exe = domain.compile_hlo(&manifest.dir.join(hlo), true)?;
    let mut param_bufs = Vec::new();
    for i in range.clone() {
        param_bufs.extend(weights.layer_buffers(domain.client(), &manifest.layers[i])?);
    }
    let last = range.end.max(1) - 1;
    let first = range.start;
    Ok(LayerExec {
        manifest: LayerManifest {
            index: usize::MAX,
            name: format!("fused[{first}..{})", range.end),
            kind: "fused".into(),
            hlo: hlo.clone(),
            input_shape: if first == 0 {
                manifest.input_shape.clone()
            } else {
                manifest.layers[first].input_shape.clone()
            },
            output_shape: manifest.layers[last].output_shape.clone(),
            output_bytes: manifest.layers[last].output_bytes,
            flops: manifest.layers[range].iter().map(|l| l.flops).sum(),
            params: vec![],
        },
        exe,
        param_bufs,
    })
}

/// Literal has no Clone in the xla crate; round-trip through raw f32.
pub fn clone_literal(l: &Literal) -> Literal {
    let shape = l
        .array_shape()
        .expect("clone_literal: non-array literal");
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().expect("clone_literal: non-f32 literal");
    literal_from_f32(&dims, &data).expect("clone_literal: rebuild")
}
