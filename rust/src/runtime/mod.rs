//! Runtime bridge: loads the AOT HLO artifacts and executes them on the
//! PJRT CPU client from the Rust hot path (Python never runs at request
//! time).
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `PjRtClient::compile` -> `execute`.
//! HLO *text* is the interchange format (jax >= 0.5 protos are rejected by
//! the bundled xla_extension 0.5.1).
//!
//! Bring-up is parallel by default: partition units compile and stage their
//! weights concurrently on a small in-tree worker pool (scoped threads, no
//! external crates), because pipeline initialisation is the body of every
//! downtime window in the paper's equations. `NEUKONFIG_SERIAL_BRINGUP=1`
//! forces the serial path; [`BuildOptions`] gives callers explicit control.

pub mod weights;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::clock::{Clock, Stopwatch};
use crate::util::sync::lock_clean;
use crate::models::{LayerManifest, ModelManifest};
pub use weights::WeightStore;

/// How a chain bring-up runs: cache usage + parallelism.
#[derive(Debug, Clone, Copy)]
pub struct BuildOptions {
    /// Use the per-domain executable and weight-buffer caches. Dynamic
    /// Switching's proactive design sets this; the naive Pause-and-Resume
    /// baseline clears/bypasses both (the Keras app reloads from scratch).
    pub use_cache: bool,
    /// Compile + stage layers concurrently on a worker pool.
    pub parallel: bool,
    /// Worker-pool size; 0 = min(available parallelism, layer count).
    pub max_workers: usize,
    /// Byte budget (in MiB) applied to the domain's weight-buffer cache
    /// before this build. `None` leaves the domain's current budget —
    /// which defaults from `NEUKONFIG_WEIGHT_CACHE_MB` — untouched. The
    /// budget is the paper's memory-vs-downtime trade-off as a knob: a
    /// smaller cache means lower steady-state edge memory, but repartitions
    /// re-pay weight uploads for evicted layers.
    pub weight_cache_mb: Option<f64>,
    /// Activation-transfer codec for the edge->cloud hand-off (defaults
    /// from `NEUKONFIG_TRANSFER_CODEC`; `Fp32` is the lossless baseline).
    /// Pipelines built with these options encode the split tensor before
    /// it enters the shaped link and decode it cloud-side.
    pub transfer_codec: crate::codec::TransferCodec,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions {
            use_cache: true,
            parallel: default_parallel_bringup(),
            max_workers: 0,
            weight_cache_mb: None,
            transfer_codec: crate::codec::TransferCodec::from_env(),
        }
    }
}

impl BuildOptions {
    pub fn serial(use_cache: bool) -> Self {
        BuildOptions { use_cache, parallel: false, ..Self::default() }
    }

    pub fn parallel(use_cache: bool) -> Self {
        BuildOptions { use_cache, parallel: true, ..Self::default() }
    }
}

/// `NEUKONFIG_SERIAL_BRINGUP=1` disables bring-up parallelism globally
/// (ablation knob; also the escape hatch for single-core CI runners).
pub fn default_parallel_bringup() -> bool {
    std::env::var("NEUKONFIG_SERIAL_BRINGUP").as_deref() != Ok("1")
}

/// Default weight-cache byte budget from `NEUKONFIG_WEIGHT_CACHE_MB`
/// (unset, unparsable, or <= 0 means unbounded — the pre-eviction
/// behaviour).
pub fn default_weight_cache_mb() -> Option<f64> {
    parse_weight_cache_mb(std::env::var("NEUKONFIG_WEIGHT_CACHE_MB").ok().as_deref())
}

fn parse_weight_cache_mb(raw: Option<&str>) -> Option<f64> {
    raw.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|mb| *mb > 0.0)
}

fn mb_to_bytes(mb: f64) -> u64 {
    (mb * 1024.0 * 1024.0) as u64
}

fn effective_workers(max_workers: usize, jobs: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let cap = if max_workers == 0 { hw } else { max_workers.min(hw) };
    cap.min(jobs).max(1)
}

/// An execution domain — the "edge server" or the "cloud server".
///
/// Each domain owns a PJRT CPU client (its "machine"). `cpu_scale` models
/// relative compute speed and CPU availability: measured execution time is
/// dilated by `1/cpu_scale` on the experiment clock (the stress-ng analogue;
/// DESIGN.md §Substitutions).
pub struct Domain {
    pub name: String,
    client: PjRtClient,
    /// Relative CPU speed (1.0 = this host's full speed), stored as f64
    /// bits so the stress controller can adjust it at runtime. The paper's
    /// cloud (8 cores) vs edge (4 cores) is modelled as cloud 2.0 vs edge
    /// 1.0; stress-ng CPU availability multiplies on top.
    cpu_scale_bits: AtomicU64,
    /// Compiled-executable cache keyed by HLO path. Per-layer artifacts
    /// mean a *repartition* never introduces a new module on a domain that
    /// has already run that layer — Dynamic Switching exploits this (the
    /// proactive design of SIII-B); the naive Pause-and-Resume baseline
    /// reloads everything uncached, like the Keras app in the paper.
    exe_cache: Mutex<HashMap<PathBuf, Arc<PjRtLoadedExecutable>>>,
    /// Staged-weight cache keyed by (layer index, layer name), mirroring
    /// `exe_cache`: once a layer's parameters are device buffers on this
    /// domain, a repartition to any split re-uses them instead of
    /// re-decoding bytes and re-uploading — `weights_upload` in the
    /// Dynamic Switching path drops to near zero. Byte-budgeted with LRU
    /// eviction for memory-constrained edges (see [`WeightCacheStats`]).
    weight_cache: Mutex<WeightCache>,
}

/// Counters + occupancy of a domain's weight-buffer cache.
///
/// Between stat resets with no intervening `clear_weight_cache`/
/// `clear_cache`, the books reconcile as
/// `misses == entries + evictions` (every miss inserts an entry that is
/// either still resident or was evicted by the budget) and
/// `hits + misses == total staging lookups`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WeightCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Resident entries right now.
    pub entries: u64,
    /// Resident staged-weight bytes right now.
    pub bytes: u64,
}

/// One staged layer in the weight cache.
struct WeightEntry {
    bufs: Arc<Vec<PjRtBuffer>>,
    bytes: u64,
    /// Monotone LRU stamp (strictly increasing — ties are impossible, so
    /// the victim order is deterministic).
    last_used: u64,
}

/// Byte-budgeted LRU over staged weight buffers. Evicting an entry only
/// drops the cache's `Arc`; chains already holding the buffers keep them
/// alive, so eviction is always safe mid-flight.
#[derive(Default)]
struct WeightCache {
    entries: HashMap<(usize, String), WeightEntry>,
    /// `None` = unbounded (the pre-eviction behaviour).
    budget_bytes: Option<u64>,
    bytes: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl WeightCache {
    fn get(&mut self, key: &(usize, String)) -> Option<Arc<Vec<PjRtBuffer>>> {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits += 1;
                Some(e.bufs.clone())
            }
            None => None,
        }
    }

    fn insert(&mut self, key: (usize, String), bufs: Arc<Vec<PjRtBuffer>>, bytes: u64) {
        self.misses += 1;
        self.tick += 1;
        self.bytes += bytes;
        if let Some(old) = self.entries.insert(
            key,
            WeightEntry { bufs, bytes, last_used: self.tick },
        ) {
            // Two builds raced on the same layer: the replaced duplicate is
            // not an eviction, just double-staged work.
            self.bytes -= old.bytes;
        }
        self.enforce_budget();
    }

    /// Evict least-recently-used entries until the cache fits its budget.
    /// An entry larger than the whole budget cannot stay resident either —
    /// the loop drains down to an empty cache if need be, so `bytes` never
    /// exceeds `budget_bytes` on return.
    fn enforce_budget(&mut self) {
        let Some(budget) = self.budget_bytes else {
            return;
        };
        while self.bytes > budget && !self.entries.is_empty() {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty cache has an LRU victim");
            if let Some(e) = self.entries.remove(&victim) {
                self.bytes -= e.bytes;
                self.evictions += 1;
            }
        }
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.bytes = 0;
    }

    fn stats(&self) -> WeightCacheStats {
        WeightCacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.entries.len() as u64,
            bytes: self.bytes,
        }
    }
}

impl Domain {
    pub fn new(name: impl Into<String>, cpu_scale: f64) -> Result<Arc<Self>> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Arc::new(Domain {
            name: name.into(),
            client,
            cpu_scale_bits: AtomicU64::new(cpu_scale.to_bits()),
            exe_cache: Mutex::new(HashMap::new()),
            weight_cache: Mutex::new(WeightCache {
                budget_bytes: default_weight_cache_mb().map(mb_to_bytes),
                ..WeightCache::default()
            }),
        }))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn cpu_scale(&self) -> f64 {
        f64::from_bits(self.cpu_scale_bits.load(Ordering::Relaxed))
    }

    /// Adjust the effective CPU speed (stress-ng analogue).
    pub fn set_cpu_scale(&self, scale: f64) {
        assert!(scale > 0.0, "cpu scale must be positive");
        self.cpu_scale_bits.store(scale.to_bits(), Ordering::Relaxed);
    }

    /// Load + compile an HLO module, with optional caching.
    pub fn compile_hlo(&self, path: &Path, use_cache: bool) -> Result<Arc<PjRtLoadedExecutable>> {
        if use_cache {
            if let Some(exe) = lock_clean(&self.exe_cache).get(path) {
                return Ok(exe.clone());
            }
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?,
        );
        if use_cache {
            lock_clean(&self.exe_cache).insert(path.to_path_buf(), exe.clone());
        }
        Ok(exe)
    }

    /// Stage one layer's parameters as device buffers, through the
    /// per-domain weight cache. Returns the buffers and whether this was a
    /// cache hit. With `use_cache = false` the cache is neither read nor
    /// populated (the naive-baseline path). The upload itself runs outside
    /// the cache lock; on a miss the staged entry is inserted afterwards
    /// and the byte budget enforced (LRU eviction).
    pub fn layer_weight_buffers(
        &self,
        weights: &WeightStore,
        layer: &LayerManifest,
        use_cache: bool,
    ) -> Result<(Arc<Vec<PjRtBuffer>>, bool)> {
        let key = (layer.index, layer.name.clone());
        if use_cache {
            if let Some(bufs) = lock_clean(&self.weight_cache).get(&key) {
                return Ok((bufs, true));
            }
        }
        let bufs = Arc::new(weights.layer_buffers(&self.client, layer)?);
        if use_cache {
            let bytes = weights.layer_staged_bytes(layer)? as u64;
            lock_clean(&self.weight_cache).insert(key, bufs.clone(), bytes);
        }
        Ok((bufs, false))
    }

    pub fn cache_len(&self) -> usize {
        lock_clean(&self.exe_cache).len()
    }

    pub fn weight_cache_len(&self) -> usize {
        lock_clean(&self.weight_cache).entries.len()
    }

    /// Resident staged-weight bytes (always <= the budget when one is set).
    pub fn weight_cache_bytes(&self) -> u64 {
        lock_clean(&self.weight_cache).bytes
    }

    /// Current byte budget (`None` = unbounded).
    pub fn weight_cache_budget_bytes(&self) -> Option<u64> {
        lock_clean(&self.weight_cache).budget_bytes
    }

    /// Set (or lift, with `None`) the weight-cache byte budget. Shrinking
    /// the budget evicts immediately — the memory knob takes effect without
    /// waiting for the next staging.
    pub fn set_weight_cache_budget_mb(&self, mb: Option<f64>) {
        let mut cache = lock_clean(&self.weight_cache);
        cache.budget_bytes = mb.filter(|m| *m > 0.0).map(mb_to_bytes);
        cache.enforce_budget();
    }

    /// Peek whether a layer is resident, without touching LRU order or the
    /// hit/miss counters (test/observability hook).
    pub fn weight_cache_contains(&self, index: usize, name: &str) -> bool {
        lock_clean(&self.weight_cache)
            .entries
            .contains_key(&(index, name.to_string()))
    }

    /// Cache counters + occupancy since construction (or the last
    /// [`Self::reset_weight_cache_stats`]).
    pub fn weight_cache_stats(&self) -> WeightCacheStats {
        lock_clean(&self.weight_cache).stats()
    }

    pub fn reset_weight_cache_stats(&self) {
        let mut cache = lock_clean(&self.weight_cache);
        cache.hits = 0;
        cache.misses = 0;
        cache.evictions = 0;
    }

    /// Drop every cached executable *and* staged weight buffer — the
    /// invalidation path that keeps the Pause-and-Resume ablation honest
    /// (the naive app tears its whole model down).
    pub fn clear_cache(&self) {
        lock_clean(&self.exe_cache).clear();
        lock_clean(&self.weight_cache).clear();
    }

    /// Drop only the staged weight buffers (zeroes occupancy; counters are
    /// left for [`Self::reset_weight_cache_stats`]).
    pub fn clear_weight_cache(&self) {
        lock_clean(&self.weight_cache).clear();
    }
}

/// f32 slice to native-endian bytes — the safe replacement for the
/// `from_raw_parts` cast this path used to carry. Pure (no FFI), so Miri
/// can check it; the copy is vanishingly cheap next to the PJRT upload the
/// bytes feed.
pub fn f32s_to_ne_bytes(data: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_ne_bytes());
    }
    bytes
}

/// f32 literal from a host slice (frame upload helper).
pub fn literal_from_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if expected != data.len() {
        anyhow::bail!("literal shape {shape:?} needs {expected} floats, got {}", data.len());
    }
    let bytes = f32s_to_ne_bytes(data);
    Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, &bytes)
        .map_err(|e| anyhow!("creating literal: {e:?}"))
}

/// Cost breakdown of building a chain (the "model load" part of pipeline
/// initialisation the paper's downtime windows contain).
///
/// Wall-clock and cumulative-CPU are reported separately because bring-up
/// is parallel: the downtime equations consume wall-clock (what the service
/// outage actually lasted), while the CPU fields keep the books honest
/// about how much work the pool did (and what a serial bring-up would have
/// paid). In the serial path the two coincide.
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    /// Wall-clock share of the build spent compiling. Under parallel
    /// bring-up the per-phase wall is not separable, so the total build
    /// wall is apportioned by each phase's CPU share.
    pub compile: Duration,
    /// Wall-clock share of the build spent staging weights.
    pub weights_upload: Duration,
    /// Cumulative CPU time across all workers spent compiling.
    pub compile_cpu: Duration,
    /// Cumulative CPU time across all workers staging weights.
    pub weights_upload_cpu: Duration,
    pub num_layers: usize,
    /// Weight-buffer cache hits/misses during this build.
    pub weight_cache_hits: u64,
    pub weight_cache_misses: u64,
}

/// One compiled partition unit, ready to execute.
///
/// Parameters are staged as device buffers once and shared (`Arc`) through
/// the per-domain weight cache; per-frame execution chains device buffers
/// between layers and reads back to the host only at the chain boundary
/// (EXPERIMENTS.md §Perf).
pub struct LayerExec {
    pub manifest: LayerManifest,
    exe: Arc<PjRtLoadedExecutable>,
    param_bufs: Arc<Vec<PjRtBuffer>>,
}

impl LayerExec {
    /// Execute on a device buffer, returning the output device buffer
    /// (no host readback) — the hot-path form.
    pub fn run_buf(&self, input: &PjRtBuffer) -> Result<PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(1 + self.param_bufs.len());
        args.push(input);
        args.extend(self.param_bufs.iter());
        let mut out = self
            .exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.manifest.name))?;
        Ok(out.remove(0).remove(0))
    }

    /// Literal-in/literal-out execution with a full host round trip — used
    /// by the per-layer profiler where each layer is timed in isolation.
    pub fn run(&self, input: &Literal) -> Result<Literal> {
        let client = self.exe.client();
        let in_buf = client
            .buffer_from_host_literal(None, input)
            .map_err(|e| anyhow!("upload {}: {e:?}", self.manifest.name))?;
        let out = self.run_buf(&in_buf)?;
        out.to_literal_sync()
            .map_err(|e| anyhow!("readback {}: {e:?}", self.manifest.name))
    }
}

/// Per-run timing of a chain execution.
#[derive(Debug, Clone, Default)]
pub struct ChainTiming {
    /// Total execution time on the experiment clock (dilated by cpu_scale).
    pub total: Duration,
    /// Per-layer dilated times, aligned with the chain's layer range
    /// (`per_layer[j]` is unit `range.start + j`). Timestamps bracket each
    /// unit's dispatch on the hot path — the chain-boundary host upload and
    /// readback are excluded, so the sum is <= `total`.
    pub per_layer: Vec<Duration>,
}

/// A compiled chain of consecutive partition units on one domain — one side
/// (edge or cloud) of an edge-cloud pipeline.
pub struct ChainExecutor {
    pub domain: Arc<Domain>,
    pub range: std::ops::Range<usize>,
    layers: Vec<LayerExec>,
    pub build_stats: BuildStats,
}

/// (layer, compile time, upload time, weight-cache hit) for one unit.
type BuiltLayer = (LayerExec, Duration, Duration, bool);

impl ChainExecutor {
    /// Compile units `range` of `manifest` on `domain` and stage their
    /// weights. This is real measured work — the heart of every pipeline
    /// initialisation cost in the paper's downtime equations.
    pub fn build(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
    ) -> Result<Self> {
        Self::build_with(domain, manifest, range, weights, BuildOptions::default())
    }

    /// [`Self::build`] without the executable/weight caches — models a naive
    /// application that reloads the model from scratch (the Pause-and-
    /// Resume baseline).
    pub fn build_uncached(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
    ) -> Result<Self> {
        Self::build_opts(domain, manifest, range, weights, false)
    }

    /// Back-compat shim: cache control only, default parallelism.
    pub fn build_opts(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
        use_cache: bool,
    ) -> Result<Self> {
        Self::build_with(
            domain,
            manifest,
            range,
            weights,
            BuildOptions { use_cache, ..Default::default() },
        )
    }

    /// Full-control build: serial or pooled-parallel bring-up.
    pub fn build_with(
        domain: Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
        opts: BuildOptions,
    ) -> Result<Self> {
        anyhow::ensure!(range.end <= manifest.num_layers(), "range out of bounds");
        if let Some(mb) = opts.weight_cache_mb {
            // Explicit per-build override of the domain's cache budget
            // (sticky — the domain keeps enforcing it afterwards).
            domain.set_weight_cache_budget_mb(Some(mb));
        }
        let t_build = Stopwatch::start();
        let built = if opts.parallel && range.len() > 1 {
            Self::build_layers_parallel(&domain, manifest, range.clone(), weights, opts)?
        } else {
            Self::build_layers_serial(&domain, manifest, range.clone(), weights, opts)?
        };
        let wall = t_build.elapsed();

        let mut layers = Vec::with_capacity(built.len());
        let mut compile_cpu = Duration::ZERO;
        let mut upload_cpu = Duration::ZERO;
        let mut hits = 0u64;
        let mut misses = 0u64;
        for (layer, compile, upload, hit) in built {
            compile_cpu += compile;
            upload_cpu += upload;
            if hit {
                hits += 1;
            } else {
                misses += 1;
            }
            layers.push(layer);
        }
        // Apportion the build wall between the two phases by CPU share so
        // `compile + weights_upload ~= wall` even when workers overlap.
        let cpu_total = compile_cpu + upload_cpu;
        let (compile_wall, upload_wall) = if cpu_total.is_zero() {
            (Duration::ZERO, Duration::ZERO)
        } else {
            let frac = compile_cpu.as_secs_f64() / cpu_total.as_secs_f64();
            (wall.mul_f64(frac), wall.mul_f64(1.0 - frac))
        };
        Ok(ChainExecutor {
            domain,
            range: range.clone(),
            build_stats: BuildStats {
                compile: compile_wall,
                weights_upload: upload_wall,
                compile_cpu,
                weights_upload_cpu: upload_cpu,
                num_layers: range.len(),
                weight_cache_hits: hits,
                weight_cache_misses: misses,
            },
            layers,
        })
    }

    fn build_one(
        domain: &Domain,
        manifest: &ModelManifest,
        i: usize,
        weights: &WeightStore,
        use_cache: bool,
    ) -> Result<BuiltLayer> {
        let lm = &manifest.layers[i];
        let t0 = Stopwatch::start();
        let exe = domain.compile_hlo(&manifest.hlo_path(i), use_cache)?;
        let compile = t0.elapsed();

        let t1 = Stopwatch::start();
        let (param_bufs, hit) = domain
            .layer_weight_buffers(weights, lm, use_cache)
            .with_context(|| format!("weights for {}", lm.name))?;
        let upload = t1.elapsed();

        Ok((LayerExec { manifest: lm.clone(), exe, param_bufs }, compile, upload, hit))
    }

    fn build_layers_serial(
        domain: &Domain,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
        opts: BuildOptions,
    ) -> Result<Vec<BuiltLayer>> {
        range
            .map(|i| Self::build_one(domain, manifest, i, weights, opts.use_cache))
            .collect()
    }

    /// Pooled bring-up: a shared atomic cursor hands unit indices to
    /// scoped worker threads; results land in per-unit slots so chain
    /// order is preserved regardless of completion order.
    fn build_layers_parallel(
        domain: &Arc<Domain>,
        manifest: &ModelManifest,
        range: std::ops::Range<usize>,
        weights: &WeightStore,
        opts: BuildOptions,
    ) -> Result<Vec<BuiltLayer>> {
        let indices: Vec<usize> = range.collect();
        let n = indices.len();
        let workers = effective_workers(opts.max_workers, n);
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<BuiltLayer>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k >= n || lock_clean(&failure).is_some() {
                        break;
                    }
                    match Self::build_one(domain, manifest, indices[k], weights, opts.use_cache)
                    {
                        Ok(built) => *lock_clean(&slots[k]) = Some(built),
                        Err(e) => {
                            lock_clean(&failure).get_or_insert(e);
                            break;
                        }
                    }
                });
            }
        });

        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(k, slot)| {
                slot.into_inner()
                    .unwrap()
                    .ok_or_else(|| anyhow!("parallel bring-up lost unit {}", indices[k]))
            })
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Execute the chain, chaining device buffers between layers (one
    /// upload, one readback). Real wall time is measured end-to-end; the
    /// difference implied by `cpu_scale` is injected on `clock` so stressed
    /// or slower domains take proportionally longer on the timeline.
    /// [`ChainTiming::per_layer`] is filled from cheap per-unit stopwatch
    /// reads (nanoseconds against PJRT execution cost), dilated by the
    /// same `cpu_scale`.
    pub fn run(&self, input: &Literal, clock: &Clock) -> Result<(Literal, ChainTiming)> {
        let t0 = Stopwatch::start();
        let (out, raw_per_layer) = self.run_raw_timed(input)?;
        let real = t0.elapsed();
        let scale = self.domain.cpu_scale().max(1e-3);
        let dilated = real.mul_f64(1.0 / scale);
        if dilated > real {
            clock.advance(dilated - real);
        }
        let per_layer = raw_per_layer
            .into_iter()
            .map(|d| d.mul_f64(1.0 / scale))
            .collect();
        Ok((out, ChainTiming { total: dilated, per_layer }))
    }

    /// Execute without timing dilation (profiling / warmup).
    pub fn run_raw(&self, input: &Literal) -> Result<Literal> {
        Ok(self.run_raw_timed(input)?.0)
    }

    /// [`Self::run_raw`] plus the undilated per-unit times (one entry per
    /// layer of this chain, in chain order).
    pub fn run_raw_timed(&self, input: &Literal) -> Result<(Literal, Vec<Duration>)> {
        if self.layers.is_empty() {
            return Ok((clone_literal(input)?, Vec::new()));
        }
        let client = self.domain.client();
        let mut buf = client
            .buffer_from_host_literal(None, input)
            .map_err(|e| anyhow!("chain input upload: {e:?}"))?;
        let mut per_layer = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let t = Stopwatch::start();
            buf = layer.run_buf(&buf)?;
            per_layer.push(t.elapsed());
        }
        let out = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("chain readback: {e:?}"))?;
        Ok((out, per_layer))
    }

    pub fn layer(&self, i: usize) -> &LayerExec {
        &self.layers[i]
    }
}

/// Build a single-module executor for a fused partition artifact
/// (ablation counterpart of the per-layer chain; see
/// rust/benches/ablation_fused.rs). `side` selects edge (units [0, split))
/// or cloud (units [split, N)); parameters are the concatenation of the
/// covered units' parameters in declaration order.
pub fn build_fused_exec(
    domain: Arc<Domain>,
    manifest: &ModelManifest,
    entry: &crate::models::FusedEntry,
    edge_side: bool,
    weights: &WeightStore,
) -> Result<LayerExec> {
    let hlo = if edge_side { &entry.edge_hlo } else { &entry.cloud_hlo };
    let hlo = hlo
        .as_ref()
        .ok_or_else(|| anyhow!("fused entry at split {} has no such side", entry.split))?;
    let range = if edge_side {
        0..entry.split
    } else {
        entry.split..manifest.num_layers()
    };
    let exe = domain.compile_hlo(&manifest.dir.join(hlo), true)?;
    // Fused modules take the concatenated parameter list, which cannot
    // share the per-layer cached Arcs — stage directly.
    let mut param_bufs = Vec::new();
    for i in range.clone() {
        param_bufs.extend(weights.layer_buffers(domain.client(), &manifest.layers[i])?);
    }
    let last = range.end.max(1) - 1;
    let first = range.start;
    Ok(LayerExec {
        manifest: LayerManifest {
            index: usize::MAX,
            name: format!("fused[{first}..{})", range.end),
            kind: "fused".into(),
            hlo: hlo.clone(),
            input_shape: if first == 0 {
                manifest.input_shape.clone()
            } else {
                manifest.layers[first].input_shape.clone()
            },
            output_shape: manifest.layers[last].output_shape.clone(),
            output_bytes: manifest.layers[last].output_bytes,
            flops: manifest.layers[range].iter().map(|l| l.flops).sum(),
            params: vec![],
        },
        exe,
        param_bufs: Arc::new(param_bufs),
    })
}

/// Literal has no Clone in the xla crate; copy the raw bytes straight into
/// the new literal (single copy — no `to_vec::<f32>` decode/rebuild round
/// trip).
pub fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("clone_literal: non-array literal: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let expected: usize = dims.iter().product::<usize>() * 4;
    let raw = l.raw_buf();
    anyhow::ensure!(
        raw.len() == expected,
        "clone_literal: {} raw bytes but f32 shape {dims:?} needs {expected}",
        raw.len()
    );
    Literal::create_from_shape_and_untyped_data(ElementType::F32, &dims, raw)
        .map_err(|e| anyhow!("clone_literal: rebuild: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32s_to_ne_bytes_round_trips() {
        // Pure byte-level test (no PJRT): Miri-clean by construction, it
        // pins the safe conversion that replaced the old from_raw_parts
        // cast in literal_from_f32.
        let data = [0.0f32, -1.5, f32::MIN_POSITIVE, f32::MAX, f32::NEG_INFINITY];
        let bytes = f32s_to_ne_bytes(&data);
        assert_eq!(bytes.len(), data.len() * 4);
        for (i, v) in data.iter().enumerate() {
            let mut word = [0u8; 4];
            word.copy_from_slice(&bytes[i * 4..i * 4 + 4]);
            assert_eq!(f32::from_ne_bytes(word), *v);
        }
        assert!(f32s_to_ne_bytes(&[]).is_empty());
        // NaN survives as a bit pattern even though NaN != NaN.
        let nan_bytes = f32s_to_ne_bytes(&[f32::NAN]);
        assert_eq!(nan_bytes, f32::NAN.to_ne_bytes());
    }

    #[test]
    fn build_options_defaults() {
        let o = BuildOptions::default();
        assert!(o.use_cache);
        assert_eq!(o.max_workers, 0);
        assert_eq!(o.weight_cache_mb, None);
        // Tests never set NEUKONFIG_TRANSFER_CODEC: the default is the
        // lossless baseline.
        assert_eq!(o.transfer_codec, crate::codec::TransferCodec::Fp32);
        let s = BuildOptions::serial(false);
        assert!(!s.parallel);
        assert!(!s.use_cache);
        let p = BuildOptions::parallel(true);
        assert!(p.parallel);
        assert!(p.use_cache);
        assert_eq!(p.weight_cache_mb, None);
    }

    #[test]
    fn weight_cache_mb_parsing() {
        assert_eq!(parse_weight_cache_mb(None), None);
        assert_eq!(parse_weight_cache_mb(Some("")), None);
        assert_eq!(parse_weight_cache_mb(Some("nope")), None);
        assert_eq!(parse_weight_cache_mb(Some("0")), None);
        assert_eq!(parse_weight_cache_mb(Some("-4")), None);
        assert_eq!(parse_weight_cache_mb(Some("64")), Some(64.0));
        assert_eq!(parse_weight_cache_mb(Some(" 2.5 ")), Some(2.5));
        assert_eq!(mb_to_bytes(1.0), 1024 * 1024);
        assert_eq!(mb_to_bytes(0.5), 512 * 1024);
    }

    #[test]
    fn weight_cache_lru_bookkeeping() {
        // Pure cache-policy test over empty buffer lists (no PJRT needed).
        let mut c = WeightCache { budget_bytes: Some(100), ..WeightCache::default() };
        let key = |i: usize| (i, format!("l{i}"));
        let bufs = || Arc::new(Vec::new());
        c.insert(key(0), bufs(), 40);
        c.insert(key(1), bufs(), 40);
        assert_eq!(c.bytes, 80);
        // Touch 0 so 1 becomes the LRU victim.
        assert!(c.get(&key(0)).is_some());
        c.insert(key(2), bufs(), 40);
        assert_eq!(c.bytes, 80);
        assert!(c.entries.contains_key(&key(0)));
        assert!(!c.entries.contains_key(&key(1)), "LRU victim must be 1");
        assert!(c.entries.contains_key(&key(2)));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 3, 1));
        assert_eq!(s.misses, s.entries + s.evictions, "books must reconcile");
        // An entry bigger than the whole budget cannot stay resident.
        c.insert(key(3), bufs(), 500);
        assert_eq!(c.entries.len(), 0);
        assert_eq!(c.bytes, 0);
        // Duplicate insert (racing builds) replaces without double counting.
        let mut d = WeightCache::default();
        d.insert(key(7), bufs(), 10);
        d.insert(key(7), bufs(), 10);
        assert_eq!(d.bytes, 10);
        assert_eq!(d.entries.len(), 1);
        assert_eq!(d.stats().evictions, 0);
    }

    #[test]
    fn worker_count_bounded() {
        assert_eq!(effective_workers(0, 0), 1);
        assert_eq!(effective_workers(0, 1), 1);
        assert_eq!(effective_workers(1, 64), 1);
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        assert!(effective_workers(0, 1024) <= hw);
        assert!(effective_workers(2, 1024) <= 2);
    }
}
