//! `neukonfig_lint` — repo-specific static analysis for the concurrency
//! and determinism invariants the NEUKONFIG reproduction depends on.
//!
//! The headline result (sub-millisecond Dynamic Switching downtime) rests
//! on concurrent hand-offs being correct *and* on experiment timelines
//! being deterministic. Five invariants are load-bearing enough to enforce
//! as hard errors over `rust/src` (DESIGN.md §Concurrency invariants):
//!
//! 1. **`bare_lock`** — no `.lock().unwrap()` / `.read().unwrap()` /
//!    `.write().unwrap()` (or `.expect(...)`) outside `util/sync.rs`. A
//!    panicking stage thread poisons its mutexes; bare unwraps cascade
//!    that panic into the router/monitor. Use the poison-recovering
//!    helpers `lock_clean` / `read_clean` / `write_clean`.
//! 2. **`wall_clock`** — no `Instant::now()` / `SystemTime::now()`
//!    outside `clock.rs`. All timing flows through the virtual [`Clock`]
//!    or its [`Stopwatch`], so fault/bandwidth schedules replay
//!    deterministically and Eq. 1–5 decompositions stay attributable.
//! 3. **`unsafe_code`** — no `unsafe` outside an explicit allowlist, and
//!    even allowlisted blocks must carry a `// SAFETY:` comment within the
//!    three preceding lines.
//! 4. **`unbounded_channel`** — no unbounded `mpsc::channel()` in
//!    coordinator code; the runner's backpressure (flat edge memory)
//!    depends on bounded `sync_channel` depths.
//! 5. **`raw_sleep`** — no `std::thread::sleep` outside `clock.rs`;
//!    waiting goes through `Clock::sleep` (so simulated timelines advance
//!    instead of blocking) or the transfer `RetryPolicy`.
//!
//! A violation can be waived line-by-line with an explicit marker in a
//! comment on the same line or the line above:
//! `neukonfig_lint: allow(<rule>) — <reason>`. Code under a
//! `#[cfg(test)]` item is skipped (tests legitimately sleep and unwrap).
//!
//! The implementation is deliberately `syn`-free — the offline build
//! environment has no proc-macro crates — so this is a comment/string/
//! char-literal-aware token scrubber plus whitespace-insensitive pattern
//! matching over the scrubbed stream. That is exact enough for these five
//! rules, all of which are token-sequence properties.
//!
//! [`Clock`]: crate::clock::Clock
//! [`Stopwatch`]: crate::clock::Stopwatch

use std::fmt;
use std::path::{Path, PathBuf};

/// The enforced invariants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    BareLock,
    WallClock,
    UnsafeCode,
    UnboundedChannel,
    RawSleep,
}

impl Rule {
    pub const ALL: [Rule; 5] = [
        Rule::BareLock,
        Rule::WallClock,
        Rule::UnsafeCode,
        Rule::UnboundedChannel,
        Rule::RawSleep,
    ];

    /// Marker name used in `neukonfig_lint: allow(<name>)`.
    pub fn name(self) -> &'static str {
        match self {
            Rule::BareLock => "bare_lock",
            Rule::WallClock => "wall_clock",
            Rule::UnsafeCode => "unsafe_code",
            Rule::UnboundedChannel => "unbounded_channel",
            Rule::RawSleep => "raw_sleep",
        }
    }

    /// One-line fix hint shown with each finding.
    pub fn hint(self) -> &'static str {
        match self {
            Rule::BareLock => {
                "use util::sync::{lock_clean, read_clean, write_clean} — bare unwraps \
                 cascade a stage panic through every thread that touches the lock"
            }
            Rule::WallClock => {
                "route timing through clock::Clock or clock::Stopwatch — stray wall-clock \
                 reads break fault/bandwidth timeline determinism (Eq. 1–5)"
            }
            Rule::UnsafeCode => {
                "remove the unsafe block, or allowlist the file AND justify it with a \
                 `// SAFETY:` comment within the 3 preceding lines"
            }
            Rule::UnboundedChannel => {
                "use std::sync::mpsc::sync_channel(depth) — runner backpressure (flat \
                 edge memory) depends on bounded hand-off depths"
            }
            Rule::RawSleep => {
                "wait via Clock::sleep (simulated timelines advance instead of blocking) \
                 or the transfer RetryPolicy"
            }
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: PathBuf,
    /// 1-based line of the match start.
    pub line: usize,
    pub rule: Rule,
    /// The offending raw source line, trimmed.
    pub snippet: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file.display(),
            self.line,
            self.rule,
            self.snippet
        )
    }
}

/// Lint configuration — the committed policy lives in [`LintConfig::default`].
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Path suffixes (with `/` separators) where `unsafe` is permitted
    /// when accompanied by a `// SAFETY:` comment. Empty by default: the
    /// one historical unsafe block (`runtime::literal_from_f32`'s
    /// `from_raw_parts` cast) was replaced with a safe byte copy.
    pub unsafe_allowlist: Vec<String>,
    /// Path suffixes exempt from `bare_lock` (the helpers themselves).
    pub bare_lock_exempt: Vec<String>,
    /// Path suffixes exempt from `wall_clock` and `raw_sleep` (the clock
    /// module is the wall-clock authority).
    pub clock_exempt: Vec<String>,
    /// `unbounded_channel` applies only to files whose path contains one
    /// of these components (coordinator hand-off code).
    pub channel_scope: Vec<String>,
}

impl Default for LintConfig {
    fn default() -> Self {
        LintConfig {
            unsafe_allowlist: vec![],
            bare_lock_exempt: vec!["util/sync.rs".into()],
            clock_exempt: vec!["clock.rs".into()],
            channel_scope: vec!["coordinator/".into()],
        }
    }
}

fn norm(path: &Path) -> String {
    path.to_string_lossy().replace('\\', "/")
}

/// Suffix match on whole path components: `clock.rs` matches `clock.rs`
/// and `rust/src/clock.rs` but NOT `wall_clock.rs`.
fn suffix_match(path: &str, suffixes: &[String]) -> bool {
    suffixes.iter().any(|s| {
        path.ends_with(s.as_str()) && {
            let head = &path[..path.len() - s.len()];
            head.is_empty() || head.ends_with('/')
        }
    })
}

fn component_match(path: &str, parts: &[String]) -> bool {
    parts.iter().any(|p| path.contains(p.as_str()))
}

/// Strip comments, string/char literals from `src`, preserving line
/// structure (every removed char that is not a newline becomes a space).
/// Rust block comments nest; raw strings (`r#"..."#`, any hash depth, with
/// optional `b` prefix) are handled; `'a` lifetimes are distinguished from
/// char literals.
pub fn scrub(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let n = b.len();
    // Push `c` or its blank placeholder, preserving newlines.
    let blank = |out: &mut String, c: char| {
        out.push(if c == '\n' { '\n' } else { ' ' });
    };
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                blank(&mut out, b[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (nesting).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string (r"...", r#"..."#, br"...").
        let raw_start = (c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r'))
            && (i == 0 || !is_ident(b[i.saturating_sub(1)]));
        if raw_start {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                // Blank the prefix + opening quote.
                while i <= j {
                    blank(&mut out, b[i]);
                    i += 1;
                }
                // Scan for `"` followed by `hashes` hashes.
                'raw: while i < n {
                    if b[i] == '"' {
                        let mut k = 0;
                        while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                blank(&mut out, b[i]);
                                i += 1;
                            }
                            break 'raw;
                        }
                    }
                    blank(&mut out, b[i]);
                    i += 1;
                }
                continue;
            }
            // Not a raw string after all — fall through as a plain char.
        }
        // Plain string literal.
        if c == '"' {
            blank(&mut out, c);
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                    continue;
                }
                let done = b[i] == '"';
                blank(&mut out, b[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime: a char literal closes within a few
        // chars (`'x'`, `'\n'`, `'\u{1F600}'`); a lifetime never closes.
        if c == '\'' {
            let mut j = i + 1;
            if j < n && b[j] == '\\' {
                j += 2;
                while j < n && b[j] != '\'' && b[j] != '\n' {
                    j += 1;
                }
            } else if j < n {
                j += 1;
            }
            if j < n && b[j] == '\'' && j > i + 1 {
                while i <= j {
                    blank(&mut out, b[i]);
                    i += 1;
                }
                continue;
            }
            // Lifetime (or stray quote): keep scanning normally.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// The scrubbed file compacted to a whitespace-free stream, with a map
/// from compact index back to the 1-based source line.
struct Compact {
    text: String,
    line_of: Vec<usize>,
}

fn compact(scrubbed: &str) -> Compact {
    let mut text = String::with_capacity(scrubbed.len());
    let mut line_of = Vec::with_capacity(scrubbed.len());
    let mut line = 1usize;
    for c in scrubbed.chars() {
        if c == '\n' {
            line += 1;
        } else if !c.is_whitespace() {
            text.push(c);
            line_of.push(line);
        }
    }
    Compact { text, line_of }
}

/// 1-based line ranges covered by `#[cfg(test)]` items (attribute through
/// the matching close brace of the following item), found by brace-matching
/// in the compact stream and mapped back to source lines so matches from
/// either text form can consult them.
fn test_line_regions(c: &Compact) -> Vec<(usize, usize)> {
    const ATTR: &str = "#[cfg(test)]";
    let mut regions = Vec::new();
    let mut from = 0;
    while let Some(pos) = c.text[from..].find(ATTR) {
        let start = from + pos;
        let mut i = start + ATTR.len();
        let bytes = c.text.as_bytes();
        // Find the item's opening brace, then brace-match to its close.
        while i < bytes.len() && bytes[i] != b'{' {
            i += 1;
        }
        let mut depth = 0usize;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        let start_line = c.line_of.get(start).copied().unwrap_or(1);
        // An unterminated item (EOF before the close brace) covers the
        // rest of the file.
        let end_line = c.line_of.get(i).copied().unwrap_or(usize::MAX);
        regions.push((start_line, end_line));
        from = i.min(bytes.len()).max(start + ATTR.len());
    }
    regions
}

fn in_regions(line: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(a, b)| line >= a && line <= b)
}

/// Does `raw_lines[line-1]` or the line above carry the allow marker for
/// `rule`?
fn allowed(raw_lines: &[&str], line: usize, rule: Rule) -> bool {
    let marker = format!("neukonfig_lint: allow({})", rule.name());
    let lo = line.saturating_sub(2); // 0-based index of the line above
    raw_lines
        .iter()
        .skip(lo)
        .take(if line >= 2 { 2 } else { 1 })
        .any(|l| l.contains(&marker))
}

/// Is there a `// SAFETY:` comment on `line` or the 3 lines above it?
fn safety_commented(raw_lines: &[&str], line: usize) -> bool {
    let lo = line.saturating_sub(4);
    raw_lines
        .iter()
        .skip(lo)
        .take(line - lo)
        .any(|l| l.contains("SAFETY:"))
}

/// All positions in `text` where `pat` occurs.
fn find_all(text: &str, pat: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = text[from..].find(pat) {
        hits.push(from + pos);
        from = from + pos + 1;
    }
    hits
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lint one file's source text.
pub fn lint_source(path: &Path, src: &str, cfg: &LintConfig) -> Vec<Finding> {
    let p = norm(path);
    let raw_lines: Vec<&str> = src.lines().collect();
    let scrubbed = scrub(src);
    let c = compact(&scrubbed);
    let tests = test_line_regions(&c);
    let mut findings = Vec::new();

    let mut push = |rule: Rule, line: usize, findings: &mut Vec<Finding>| {
        if in_regions(line, &tests) {
            return;
        }
        if allowed(&raw_lines, line, rule) {
            return;
        }
        if rule == Rule::UnsafeCode
            && suffix_match(&p, &cfg.unsafe_allowlist)
            && safety_commented(&raw_lines, line)
        {
            return;
        }
        findings.push(Finding {
            file: path.to_path_buf(),
            line,
            rule,
            snippet: raw_lines
                .get(line - 1)
                .map(|l| l.trim().to_string())
                .unwrap_or_default(),
        });
    };

    // The compact (whitespace-free) stream catches call chains split
    // across lines; a compact position maps back to a source line.
    let line_at = |pos: usize| c.line_of.get(pos).copied().unwrap_or(1);

    // 1. bare_lock — poison-unsafe guard acquisition. Leading `.` in the
    //    patterns keeps `try_lock().unwrap()` out of scope.
    if !suffix_match(&p, &cfg.bare_lock_exempt) {
        for pat in [
            ".lock().unwrap()",
            ".lock().expect(",
            ".read().unwrap()",
            ".read().expect(",
            ".write().unwrap()",
            ".write().expect(",
        ] {
            for pos in find_all(&c.text, pat) {
                push(Rule::BareLock, line_at(pos), &mut findings);
            }
        }
    }

    // 2. wall_clock — stray monotonic/wall reads.
    if !suffix_match(&p, &cfg.clock_exempt) {
        for pat in ["Instant::now()", "SystemTime::now()"] {
            for pos in find_all(&c.text, pat) {
                push(Rule::WallClock, line_at(pos), &mut findings);
            }
        }
    }

    // 3. unsafe_code — keyword with word boundaries. Matched on the
    //    scrubbed (not compact) text: compaction would glue `unsafe fn`
    //    into `unsafefn` and defeat the boundary check.
    for pos in find_all(&scrubbed, "unsafe") {
        let before = scrubbed[..pos].chars().next_back();
        let after = scrubbed[pos + "unsafe".len()..].chars().next();
        if before.is_some_and(is_ident_char) || after.is_some_and(is_ident_char) {
            continue;
        }
        let line = 1 + scrubbed[..pos].matches('\n').count();
        push(Rule::UnsafeCode, line, &mut findings);
    }

    // 4. unbounded_channel — coordinator scope only.
    if component_match(&p, &cfg.channel_scope) {
        for pat in ["channel()", "channel::<"] {
            for pos in find_all(&c.text, pat) {
                // `sync_channel()` / `sync_channel::<` share the suffix;
                // reject matches whose preceding char extends the ident.
                if c.text[..pos].chars().next_back().is_some_and(is_ident_char) {
                    continue;
                }
                push(Rule::UnboundedChannel, line_at(pos), &mut findings);
            }
        }
    }

    // 5. raw_sleep — blocking waits outside the clock.
    if !suffix_match(&p, &cfg.clock_exempt) {
        for pos in find_all(&c.text, "thread::sleep(") {
            push(Rule::RawSleep, line_at(pos), &mut findings);
        }
    }

    findings.sort_by_key(|f| (f.line, f.rule.name()));
    findings
}

/// Lint every `.rs` file under `root` (a file path lints that one file).
pub fn lint_tree(root: &Path, cfg: &LintConfig) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        findings.extend(lint_source(&f, &src, cfg));
    }
    Ok(findings)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if path.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    for entry in std::fs::read_dir(path)? {
        collect_rs(&entry?.path(), out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(name: &str, src: &str) -> Vec<Finding> {
        lint_source(Path::new(name), src, &LintConfig::default())
    }

    fn rules(f: &[Finding]) -> Vec<Rule> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn scrub_strips_comments_and_strings() {
        let src = "let a = \"lock().unwrap()\"; // Instant::now()\n/* unsafe */ let b = 1;";
        let s = scrub(src);
        assert!(!s.contains("unwrap"));
        assert!(!s.contains("Instant"));
        assert!(!s.contains("unsafe"));
        assert!(s.contains("let a ="));
        assert!(s.contains("let b = 1;"));
        assert_eq!(s.lines().count(), src.lines().count());
    }

    #[test]
    fn scrub_handles_nested_and_raw() {
        let src = "/* a /* nested unsafe */ still comment */ x\nlet r = r#\"thread::sleep(\"#;";
        let s = scrub(src);
        assert!(!s.contains("unsafe"));
        assert!(!s.contains("sleep"));
        assert!(s.contains('x'));
        assert!(s.contains("let r ="));
    }

    #[test]
    fn scrub_distinguishes_lifetimes_from_chars() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }";
        let s = scrub(src);
        assert!(s.contains("fn f<'a>(x: &'a str)"));
        assert!(!s.contains("'x'"));
    }

    #[test]
    fn bare_lock_matches_across_lines() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m\n        .lock()\n        .unwrap()\n}\n";
        let f = lint_str("a.rs", src);
        assert_eq!(rules(&f), vec![Rule::BareLock]);
        assert_eq!(f[0].line, 3, "finding anchors at the .lock() line");
    }

    #[test]
    fn lock_expect_and_rwlock_variants_trip() {
        let src = "fn f() { m.lock().expect(\"x\"); l.read().unwrap(); l.write().unwrap(); }";
        assert_eq!(
            rules(&lint_str("a.rs", src)),
            vec![Rule::BareLock, Rule::BareLock, Rule::BareLock]
        );
    }

    #[test]
    fn try_lock_is_not_bare_lock() {
        let src = "fn f() { let _ = m.try_lock().unwrap(); }";
        assert!(lint_str("a.rs", src).is_empty());
    }

    #[test]
    fn sync_helpers_file_is_exempt() {
        let src = "pub fn lock_clean() { m.lock().unwrap(); }";
        assert!(lint_str("rust/src/util/sync.rs", src).is_empty());
        assert_eq!(rules(&lint_str("rust/src/other.rs", src)), vec![Rule::BareLock]);
    }

    #[test]
    fn wall_clock_outside_clock_rs_trips() {
        let src = "fn f() { let t = Instant::now(); let s = SystemTime::now(); }";
        assert_eq!(
            rules(&lint_str("rust/src/bench.rs", src)),
            vec![Rule::WallClock, Rule::WallClock]
        );
        assert!(lint_str("rust/src/clock.rs", src).is_empty());
        assert!(lint_str("clock.rs", src).is_empty());
        // The exemption is per path component: a *_clock.rs file that
        // merely shares the suffix is NOT the clock module.
        assert_eq!(
            rules(&lint_str("rust/src/wall_clock.rs", src)),
            vec![Rule::WallClock, Rule::WallClock]
        );
    }

    #[test]
    fn allow_marker_waives_same_or_previous_line() {
        let same = "let t = Instant::now(); // neukonfig_lint: allow(wall_clock) — pacing\n";
        assert!(lint_str("a.rs", same).is_empty());
        let above =
            "// neukonfig_lint: allow(wall_clock) — pacing\nlet t = Instant::now();\n";
        assert!(lint_str("a.rs", above).is_empty());
        let wrong_rule =
            "// neukonfig_lint: allow(raw_sleep)\nlet t = Instant::now();\n";
        assert_eq!(rules(&lint_str("a.rs", wrong_rule)), vec![Rule::WallClock]);
        let too_far =
            "// neukonfig_lint: allow(wall_clock)\n\nlet t = Instant::now();\n";
        assert_eq!(rules(&lint_str("a.rs", too_far)), vec![Rule::WallClock]);
    }

    #[test]
    fn unsafe_requires_allowlist_and_safety_comment() {
        let bare = "fn f() { unsafe { g(); } }";
        assert_eq!(rules(&lint_str("a.rs", bare)), vec![Rule::UnsafeCode]);

        let commented = "// SAFETY: justified\nfn f() { unsafe { g(); } }";
        // SAFETY comment alone is not enough — the file must be allowlisted.
        assert_eq!(rules(&lint_str("a.rs", commented)), vec![Rule::UnsafeCode]);

        let cfg = LintConfig {
            unsafe_allowlist: vec!["a.rs".into()],
            ..LintConfig::default()
        };
        assert!(lint_source(Path::new("a.rs"), commented, &cfg).is_empty());
        // Allowlisted but uncommented still trips.
        assert_eq!(
            rules(&lint_source(Path::new("a.rs"), bare, &cfg)),
            vec![Rule::UnsafeCode]
        );
    }

    #[test]
    fn unsafe_is_word_bounded() {
        let src = "fn f() { let unsafety = 1; let x = not_unsafe; }";
        assert!(lint_str("a.rs", src).is_empty());
    }

    #[test]
    fn unbounded_channel_only_in_coordinator_scope() {
        let src = "fn f() { let (tx, rx) = mpsc::channel(); }";
        assert_eq!(
            rules(&lint_str("rust/src/coordinator/runner.rs", src)),
            vec![Rule::UnboundedChannel]
        );
        assert!(lint_str("rust/src/util/model.rs", src).is_empty());
        let turbofish = "fn f() { let (tx, rx) = channel::<u32>(); }";
        assert_eq!(
            rules(&lint_str("rust/src/coordinator/x.rs", turbofish)),
            vec![Rule::UnboundedChannel]
        );
    }

    #[test]
    fn bounded_sync_channel_is_fine() {
        let src = "fn f() { let (tx, rx) = sync_channel::<u32>(2); let c = sync_channel(1); }";
        assert!(lint_str("rust/src/coordinator/runner.rs", src).is_empty());
    }

    #[test]
    fn raw_sleep_trips_outside_clock() {
        let src = "fn f() { std::thread::sleep(d); }";
        assert_eq!(rules(&lint_str("rust/src/coordinator/server.rs", src)), vec![Rule::RawSleep]);
        assert!(lint_str("rust/src/clock.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { m.lock().unwrap(); std::thread::sleep(d); }\n}\n";
        assert!(lint_str("a.rs", src).is_empty());
        // ... but production code before/after still lints.
        let mixed = "fn prod() { m.lock().unwrap(); }\n#[cfg(test)]\nmod tests { fn t() { std::thread::sleep(d); } }\n";
        assert_eq!(rules(&lint_str("a.rs", mixed)), vec![Rule::BareLock]);
    }

    #[test]
    fn findings_render_with_location() {
        let f = lint_str("src/x.rs", "fn f() { m.lock().unwrap(); }");
        let shown = f[0].to_string();
        assert!(shown.contains("src/x.rs:1"), "got {shown}");
        assert!(shown.contains("bare_lock"), "got {shown}");
    }
}
