//! Poison-recovering lock helpers — substrate module.
//!
//! A panicking stage thread poisons every mutex it holds; the default
//! `lock().unwrap()` then cascades that panic into whichever thread
//! touches the lock next (the router, the monitor, a draining stage).
//! All the state these locks guard is plain counters and schedules that
//! stay internally consistent at every await point, so recovery is
//! always safe: take the guard out of the `PoisonError` and carry on.

use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult,
};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read_clean<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write_clean<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`Condvar::wait_timeout_while`] with poison recovery — the condvar
/// counterpart of [`lock_clean`] for guards parked on a notification.
pub fn wait_timeout_while_clean<'a, T, F>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
    condition: F,
) -> (MutexGuard<'a, T>, WaitTimeoutResult)
where
    F: FnMut(&mut T) -> bool,
{
    cv.wait_timeout_while(guard, timeout, condition)
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex, RwLock};
    use std::time::Duration;

    #[test]
    fn lock_clean_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_clean(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_clean(&m), 8);
    }

    #[test]
    fn condvar_wait_recovers_from_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = pair2.0.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(pair.0.is_poisoned());
        let guard = lock_clean(&pair.0);
        let (g, timed_out) = wait_timeout_while_clean(
            &pair.1,
            guard,
            Duration::from_millis(5),
            |ready| !*ready,
        );
        assert!(timed_out.timed_out());
        assert!(!*g);
    }

    #[test]
    fn rwlock_clean_recovers_from_poison() {
        let l = Arc::new(RwLock::new(3usize));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*read_clean(&l), 3);
        *write_clean(&l) = 4;
        assert_eq!(*read_clean(&l), 4);
    }
}
