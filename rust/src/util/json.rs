//! Minimal JSON parser — substrate module.
//!
//! The build environment is offline (no `serde_json`), and the only JSON we
//! consume is the artifact manifests emitted by `python/compile/aot.py`, so
//! a small recursive-descent parser is all that is needed. Supports the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null); numbers are kept as `f64` plus an exact `i64` where representable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `value["key"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; returns Null when out of bounds.
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(1).as_i64(), Some(2));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"caf\u{e9} \u{4e2d}\u{6587}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café 中文");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn missing_key_is_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Value::Null);
        assert_eq!(v.get("nope").as_usize(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(Default::default()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n\t{ \"a\" :\n1 } \r\n").unwrap();
        assert_eq!(v.get("a").as_i64(), Some(1));
    }
}
