//! Miniature concurrency model-checking harness — substrate module.
//!
//! The runner hand-off and the router switch/rollback protocols are the
//! correctness spine of Dynamic Switching, and they deserve model tests in
//! the style of the `loom` crate: run a small concurrent closure many times
//! and try to force every interleaving to the surface. The build
//! environment is offline, so this module stands in for `loom` with the
//! same API *shape* (`model`, `thread::spawn`, `sync::Mutex`,
//! `sync::mpsc::sync_channel`) over a seeded schedule perturbator:
//!
//! * each iteration re-seeds a global xorshift stream;
//! * every synchronisation point (spawn, lock, send, recv) draws from it
//!   and either yields the OS scheduler, spins briefly, or proceeds —
//!   biasing each iteration toward a different interleaving;
//! * a watchdog thread bounds every iteration, so a deadlock in the model
//!   fails the test with a named iteration instead of hanging the suite.
//!
//! This explores schedules probabilistically rather than exhaustively
//! (loom's DPOR it is not), but the API subset matches, so dropping the
//! real crate in later is a `use` swap in the tests. Iteration count:
//! `NEUKONFIG_MODEL_ITERS` (CI's model-check job raises it; the job also
//! sets `RUSTFLAGS="--cfg loom"`, which this facade accepts and ignores so
//! the command line stays loom-compatible).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Default schedule explorations per [`model`] call (kept modest so the
/// tier-1 suite stays fast; the CI model-check job raises it via env).
pub const DEFAULT_ITERS: usize = 128;

/// Per-iteration deadlock watchdog.
const WATCHDOG: Duration = Duration::from_secs(20);

/// Global perturbation stream. Re-seeded at the start of every model
/// iteration; every synchronisation point advances it with an atomic
/// xorshift step, so concurrent threads interleave their draws — which is
/// exactly the cross-thread coupling we want: one thread's progress
/// changes the schedule nudges another thread sees.
static SCHEDULE: AtomicU64 = AtomicU64::new(0x5EED);

fn draw() -> u64 {
    // Racy read-modify-write on purpose: losing an update just merges two
    // threads' draws, which perturbs schedules harder. xorshift64 step.
    let mut x = SCHEDULE.load(Ordering::Relaxed);
    if x == 0 {
        x = 0x5EED;
    }
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    SCHEDULE.store(x, Ordering::Relaxed);
    x
}

/// Schedule perturbation point: called by every wrapper below.
fn perturb() {
    match draw() % 8 {
        0 | 1 => std::thread::yield_now(),
        2 => {
            for _ in 0..(draw() % 64) {
                std::hint::spin_loop();
            }
        }
        _ => {}
    }
}

fn iters_from_env() -> usize {
    std::env::var("NEUKONFIG_MODEL_ITERS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|n| *n > 0)
        .unwrap_or(DEFAULT_ITERS)
}

/// Run `f` under the model checker: `NEUKONFIG_MODEL_ITERS` (default
/// [`DEFAULT_ITERS`]) iterations, each under a fresh schedule seed and a
/// deadlock watchdog. Panics inside the model propagate with the
/// iteration number attached.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    model_iters(iters_from_env(), f)
}

/// [`model`] with an explicit iteration count.
pub fn model_iters<F>(iters: usize, f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let f = Arc::new(f);
    for it in 0..iters {
        SCHEDULE.store(
            (it as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
            Ordering::Relaxed,
        );
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let g = Arc::clone(&f);
        let handle = std::thread::Builder::new()
            .name(format!("model-iter-{it}"))
            .spawn(move || {
                g();
                let _ = done_tx.send(());
            })
            .expect("spawn model iteration");
        match done_rx.recv_timeout(WATCHDOG) {
            Ok(()) => {
                let _ = handle.join();
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // The closure panicked before signalling: surface it.
                if let Err(payload) = handle.join() {
                    eprintln!("model iteration {it} panicked");
                    std::panic::resume_unwind(payload);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                // Leak the wedged threads; failing loudly beats hanging.
                panic!(
                    "model iteration {it} deadlocked (watchdog {WATCHDOG:?}) — \
                     a hand-off is blocking on a dead peer"
                );
            }
        }
    }
}

/// `loom::thread` subset: spawn/yield with schedule perturbation.
pub mod thread {
    /// Spawn a model thread; both the spawn point and the thread's first
    /// step are perturbation points.
    pub fn spawn<F, T>(f: F) -> std::thread::JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        super::perturb();
        std::thread::spawn(move || {
            super::perturb();
            f()
        })
    }

    pub fn yield_now() {
        std::thread::yield_now()
    }
}

/// `loom::sync` subset: perturbing wrappers over the std primitives.
pub mod sync {
    pub use std::sync::Arc;

    /// Mutex whose acquisition is a schedule perturbation point. Returns
    /// the std [`LockResult`](std::sync::LockResult), so model code reads
    /// exactly like loom code (`.lock().unwrap()`).
    #[derive(Debug, Default)]
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub fn new(t: T) -> Self {
            Mutex(std::sync::Mutex::new(t))
        }

        pub fn lock(&self) -> std::sync::LockResult<std::sync::MutexGuard<'_, T>> {
            super::perturb();
            self.0.lock()
        }
    }

    /// `loom::sync::mpsc` subset — bounded channels only, because the
    /// codebase's own lint (`unbounded_channel`) bans anything else in
    /// coordinator hand-offs.
    pub mod mpsc {
        /// Bounded channel whose send/recv are perturbation points.
        pub fn sync_channel<T>(depth: usize) -> (SyncSender<T>, Receiver<T>) {
            let (tx, rx) = std::sync::mpsc::sync_channel(depth);
            (SyncSender(tx), Receiver(rx))
        }

        pub struct SyncSender<T>(std::sync::mpsc::SyncSender<T>);

        impl<T> Clone for SyncSender<T> {
            fn clone(&self) -> Self {
                SyncSender(self.0.clone())
            }
        }

        impl<T> SyncSender<T> {
            pub fn send(&self, t: T) -> Result<(), std::sync::mpsc::SendError<T>> {
                super::super::perturb();
                self.0.send(t)
            }
        }

        pub struct Receiver<T>(std::sync::mpsc::Receiver<T>);

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, std::sync::mpsc::RecvError> {
                super::super::perturb();
                self.0.recv()
            }

            pub fn try_recv(&self) -> Result<T, std::sync::mpsc::TryRecvError> {
                super::super::perturb();
                self.0.try_recv()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_runs_every_iteration() {
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        model_iters(17, move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn racing_increments_never_lose_updates() {
        model_iters(32, || {
            let m = sync::Arc::new(sync::Mutex::new(0u64));
            let handles: Vec<_> = (0..3)
                .map(|_| {
                    let m = sync::Arc::clone(&m);
                    thread::spawn(move || {
                        for _ in 0..50 {
                            *m.lock().unwrap() += 1;
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(*m.lock().unwrap(), 150);
        });
    }

    #[test]
    fn bounded_channel_preserves_fifo_order() {
        model_iters(32, || {
            let (tx, rx) = sync::mpsc::sync_channel::<usize>(1);
            let producer = thread::spawn(move || {
                for i in 0..6 {
                    tx.send(i).expect("receiver alive");
                }
            });
            for want in 0..6 {
                assert_eq!(rx.recv().unwrap(), want);
            }
            assert!(rx.recv().is_err(), "sender dropped after 6");
            producer.join().unwrap();
        });
    }

    #[test]
    fn model_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            model_iters(1, || panic!("boom from the model"));
        });
        assert!(caught.is_err());
    }

    #[test]
    fn iters_env_parsing_falls_back() {
        // Only the fallback path is unit-testable without mutating the
        // process env; the CI model-check job exercises the override.
        assert!(DEFAULT_ITERS > 0);
        assert!(iters_from_env() > 0);
    }
}
