//! Summary statistics — substrate module (no `criterion` offline).
//!
//! Shared by the benchmark harness (`crate::bench`), the profiler, and the
//! metrics layer. All quantile math uses the nearest-rank method on a
//! sorted copy, which is exact for the sample sizes we use.

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Some(Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Nearest-rank percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Streaming mean/variance accumulator (Welford) for hot paths that cannot
/// afford to buffer samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std_dev(&self) -> f64 {
        if self.n > 1 {
            (self.m2 / (self.n - 1) as f64).sqrt()
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]).unwrap();
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.0); // nearest-rank
        assert!((s.std_dev - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_edges() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 3.0);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std_dev() - s.std_dev).abs() < 1e-9);
        assert_eq!(w.count(), 1000);
    }
}
