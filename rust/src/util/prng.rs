//! Small deterministic PRNG — substrate module.
//!
//! Used by the synthetic video source (frame pixels), the property-based
//! tests (no `proptest` offline), and workload generators. xorshift64* is
//! tiny, fast, and has well-understood statistical quality for these uses
//! (it is NOT cryptographic and is never used for security).

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn next_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform usize in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform u64 in [lo, hi].
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(42);
        for _ in 0..10_000 {
            let v = p.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_bounded() {
        let mut p = Prng::new(3);
        for _ in 0..10_000 {
            assert!(p.next_below(7) < 7);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut p = Prng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[p.next_below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn zero_seed_not_stuck() {
        let mut p = Prng::new(0);
        assert_ne!(p.next_u64(), p.next_u64());
    }
}
