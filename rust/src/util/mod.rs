//! In-tree substrate utilities: JSON parsing, deterministic PRNG, summary
//! statistics. The build environment is offline, so these replace
//! `serde_json`, `rand`, and the statistics half of `criterion`.

pub mod json;
pub mod model;
pub mod prng;
pub mod stats;
pub mod sync;
