//! Overlapped frame execution: edge compute of frame N+1 runs concurrently
//! with the transfer + cloud compute of frame N.
//!
//! Sequential `Pipeline::infer` leaves the edge idle while a frame is on
//! the wire or in the cloud — the classic pipeline bubble. The runner
//! splits each frame at the partition boundary: a producer thread runs the
//! edge chain and hands intermediates through a *bounded* channel to the
//! consumer, which does transfer + cloud. Back-pressure (the channel
//! depth) bounds in-flight frames so edge memory stays flat.
//!
//! Ordering and timing semantics are preserved exactly:
//! * frames are produced, shipped, and consumed strictly in order — a
//!   single producer and single consumer over a FIFO channel, so the
//!   returned [`InferenceReport`]s are in frame order;
//! * every report component keeps its own authority (chain-reported
//!   dilated times, [`Link::transfer`]'s returned cost), identical to the
//!   sequential path, so per-frame numbers match `infer` while wall-clock
//!   throughput improves;
//! * `cpu_scale` dilation still lands on the shared [`Clock`]: each
//!   chain's dilation surplus is injected exactly once per frame, same as
//!   sequential execution. Only real elapsed time overlaps.

use std::sync::mpsc::sync_channel;

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use crate::runtime::ChainTiming;

use super::pipeline::{InferenceReport, Pipeline};

/// Default number of in-flight intermediates between edge and cloud.
pub const DEFAULT_DEPTH: usize = 2;

/// Two-stage overlapped executor over one [`Pipeline`].
#[derive(Debug, Clone, Copy)]
pub struct PipelinedRunner {
    /// Bounded-channel capacity: how many edge outputs may be in flight
    /// before the edge stalls (1 = lock-step, still overlaps one frame).
    pub depth: usize,
}

impl Default for PipelinedRunner {
    fn default() -> Self {
        PipelinedRunner { depth: DEFAULT_DEPTH }
    }
}

impl PipelinedRunner {
    pub fn new(depth: usize) -> Self {
        PipelinedRunner { depth: depth.max(1) }
    }

    /// Run `frames` through `pipeline` with edge/cloud overlap, returning
    /// one report per frame in frame order. Fails (like
    /// [`Pipeline::infer`]) if the pipeline is not serving traffic.
    pub fn run(&self, pipeline: &Pipeline, frames: &[Literal]) -> Result<Vec<InferenceReport>> {
        if !pipeline.state().serves_traffic() {
            bail!(
                "pipeline {} is {}, not serving",
                pipeline.id,
                pipeline.state()
            );
        }
        self.run_unchecked(pipeline, frames)
    }

    /// [`Self::run`] without the state gate (warmup, benches).
    pub fn run_unchecked(
        &self,
        pipeline: &Pipeline,
        frames: &[Literal],
    ) -> Result<Vec<InferenceReport>> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        let (tx, rx) = sync_channel::<Result<(Literal, ChainTiming)>>(self.depth);
        let mut reports = Vec::with_capacity(frames.len());

        std::thread::scope(|s| -> Result<()> {
            let producer = s.spawn(move || {
                for frame in frames {
                    let staged = pipeline.edge_chain.run(frame, &pipeline.clock);
                    let failed = staged.is_err();
                    // A send error means the consumer hung up (it hit its
                    // own error and dropped `rx`) — stop producing.
                    if tx.send(staged).is_err() || failed {
                        break;
                    }
                }
            });

            for _ in 0..frames.len() {
                let (intermediate, edge_t) = match rx.recv() {
                    Ok(staged) => staged?,
                    // Producer hung up early: it already sent the error we
                    // consumed (or panicked, caught at join below).
                    Err(_) => break,
                };
                let t_transfer = pipeline.link.transfer(intermediate.size_bytes());
                let (output, cloud_t) = pipeline.cloud_chain.run(&intermediate, &pipeline.clock)?;
                reports.push(InferenceReport {
                    t_edge: edge_t.total,
                    t_transfer,
                    t_cloud: cloud_t.total,
                    output,
                });
            }
            drop(rx);
            producer
                .join()
                .map_err(|_| anyhow!("edge stage panicked"))?;
            Ok(())
        })?;

        if reports.len() != frames.len() {
            bail!(
                "pipelined run produced {} of {} reports",
                reports.len(),
                frames.len()
            );
        }
        Ok(reports)
    }
}
