//! Overlapped frame execution: the edge compute, link transfer, and cloud
//! compute stages of consecutive frames run concurrently.
//!
//! Sequential `Pipeline::infer` leaves the edge idle while a frame is on
//! the wire or in the cloud — the classic pipeline bubble. The runner
//! splits each frame at the partition boundary and runs the stages on
//! their own threads over *bounded* channels:
//!
//! * [`StageMode::Two`] — the original overlap: a producer thread runs the
//!   edge chain and hands intermediates to the consumer, which does
//!   transfer + cloud. Edge(N+1) overlaps transfer(N) + cloud(N).
//! * [`StageMode::Three`] (default) — transfer gets its own stage, so the
//!   link transfer of frame N overlaps *both* edge(N+1) and cloud(N−1).
//!   On a transfer-bound configuration this lifts throughput to
//!   `1 / max(t_edge, t_transfer, t_cloud)` instead of
//!   `1 / (t_transfer + t_cloud)`.
//!
//! Back-pressure (the channel depth) bounds in-flight frames per hand-off
//! so edge memory stays flat.
//!
//! Ordering and timing semantics are preserved exactly:
//! * frames are produced, shipped, and consumed strictly in order — one
//!   thread per stage over FIFO channels, so the returned
//!   [`InferenceReport`]s are in frame order;
//! * every report component keeps its own authority (chain-reported
//!   dilated times, [`Link::transfer`]'s returned cost), identical to the
//!   sequential path, so per-frame numbers match `infer` while wall-clock
//!   throughput improves;
//! * `cpu_scale` dilation still lands on the shared [`Clock`]: each
//!   chain's dilation surplus is injected exactly once per frame, same as
//!   sequential execution. Only real elapsed time overlaps.
//!
//! Failure semantics: a stage error is forwarded downstream (tagged with
//! the originating stage and frame index) and every stage drains cleanly —
//! dropping a receiver fails the upstream `send`, which stops that stage,
//! so no thread ever blocks on a dead peer and no out-of-order or partial
//! report is returned. One exception: a transfer abandoned by the retry
//! policy ([`TransferAborted`] — an injected-fault link exhausting its
//! attempts or deadline) drops *that frame only* (the Fig. 14/15
//! frame-drop regime; `Pipeline::fault_stats` counts it) and the burst
//! continues — a hostile link must not wedge the stage.
//!
//! [`Link::transfer`]: crate::netsim::Link::transfer
//! [`Clock`]: crate::clock::Clock

use std::sync::mpsc::sync_channel;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use crate::netsim::TransferAborted;
use crate::runtime::ChainTiming;

use super::pipeline::{InferenceReport, Pipeline, TransferReport};

/// Default number of in-flight intermediates per stage hand-off.
pub const DEFAULT_DEPTH: usize = 2;

/// How many pipeline stages run on their own threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageMode {
    /// Edge producer + (transfer, cloud) consumer — the original overlap.
    Two,
    /// Edge, transfer, and cloud each on their own stage.
    Three,
}

/// Overlapped executor over one [`Pipeline`].
#[derive(Debug, Clone, Copy)]
pub struct PipelinedRunner {
    /// Bounded-channel capacity per hand-off: how many outputs may be in
    /// flight before the upstream stage stalls (1 = lock-step, still
    /// overlaps one frame per hand-off).
    pub depth: usize,
    /// Two-stage (edge | transfer+cloud) or three-stage
    /// (edge | transfer | cloud) execution.
    pub stages: StageMode,
}

impl Default for PipelinedRunner {
    fn default() -> Self {
        PipelinedRunner { depth: DEFAULT_DEPTH, stages: StageMode::Three }
    }
}

/// Frame-indexed hand-off between stages.
type Staged<T> = (usize, Result<T>);

impl PipelinedRunner {
    /// Three-stage runner (the default) at the given depth.
    pub fn new(depth: usize) -> Self {
        PipelinedRunner { depth: depth.max(1), stages: StageMode::Three }
    }

    /// Two-stage runner — the original overlap, kept for the ablation
    /// benches and as a fallback when thread budget is tight.
    pub fn two_stage(depth: usize) -> Self {
        PipelinedRunner { depth: depth.max(1), stages: StageMode::Two }
    }

    /// Run `frames` through `pipeline` with stage overlap, returning one
    /// report per frame in frame order. Fails (like [`Pipeline::infer`])
    /// if the pipeline is not serving traffic.
    pub fn run(&self, pipeline: &Pipeline, frames: &[Literal]) -> Result<Vec<InferenceReport>> {
        if !pipeline.state().serves_traffic() {
            bail!(
                "pipeline {} is {}, not serving",
                pipeline.id,
                pipeline.state()
            );
        }
        self.run_unchecked(pipeline, frames)
    }

    /// [`Self::run`] without the state gate (warmup, benches).
    pub fn run_unchecked(
        &self,
        pipeline: &Pipeline,
        frames: &[Literal],
    ) -> Result<Vec<InferenceReport>> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        match self.stages {
            StageMode::Two => self.run_two_stage(pipeline, frames),
            StageMode::Three => self.run_three_stage(pipeline, frames),
        }
    }

    fn run_two_stage(
        &self,
        pipeline: &Pipeline,
        frames: &[Literal],
    ) -> Result<Vec<InferenceReport>> {
        let (tx, rx) = sync_channel::<Staged<(Literal, ChainTiming)>>(self.depth);
        let mut reports = Vec::with_capacity(frames.len());
        let mut dropped = 0usize;

        let edge_progress = std::thread::scope(|s| -> Result<usize> {
            let producer = s.spawn(move || {
                for (i, frame) in frames.iter().enumerate() {
                    let staged = pipeline
                        .edge_chain
                        .run(frame, &pipeline.clock)
                        .with_context(|| format!("edge stage failed at frame {i}"));
                    let failed = staged.is_err();
                    // A send error means the consumer hung up (it hit its
                    // own error and dropped `rx`) — stop producing.
                    if tx.send((i, staged)).is_err() || failed {
                        return i;
                    }
                }
                frames.len()
            });

            for _ in 0..frames.len() {
                let (i, staged) = match rx.recv() {
                    Ok(handoff) => handoff,
                    // Producer hung up without delivering an error we could
                    // consume (it panicked, caught at join below) — stop
                    // consuming; the caller's length check attributes it.
                    Err(_) => break,
                };
                let (intermediate, edge_t) = staged?;
                let (cloud_input, xfer) = match pipeline.ship(intermediate) {
                    Ok(shipped) => shipped,
                    // Retry exhaustion drops this frame, not the burst.
                    Err(e) if is_transfer_abort(&e) => {
                        dropped += 1;
                        continue;
                    }
                    Err(e) => {
                        return Err(e.context(format!("transfer stage failed at frame {i}")))
                    }
                };
                let (output, cloud_t) = pipeline
                    .cloud_chain
                    .run(&cloud_input, &pipeline.clock)
                    .with_context(|| format!("cloud stage failed at frame {i}"))?;
                reports.push(report(edge_t, xfer, cloud_t, output));
            }
            drop(rx);
            producer.join().map_err(|_| anyhow!("edge stage panicked"))
        })?;

        check_complete(reports.len(), dropped, frames.len(), &[("edge", edge_progress)])?;
        Ok(reports)
    }

    fn run_three_stage(
        &self,
        pipeline: &Pipeline,
        frames: &[Literal],
    ) -> Result<Vec<InferenceReport>> {
        let (edge_tx, edge_rx) = sync_channel::<Staged<(Literal, ChainTiming)>>(self.depth);
        // `None` in the hand-off marks a frame the transfer stage dropped
        // (retry exhaustion) — the cloud stage skips it and keeps going.
        let (link_tx, link_rx) =
            sync_channel::<Staged<Option<(Literal, ChainTiming, TransferReport)>>>(self.depth);
        let mut reports = Vec::with_capacity(frames.len());
        let mut dropped = 0usize;

        let (edge_progress, transfer_progress) =
            std::thread::scope(|s| -> Result<(usize, usize)> {
                let edge = s.spawn(move || {
                    for (i, frame) in frames.iter().enumerate() {
                        let staged = pipeline
                            .edge_chain
                            .run(frame, &pipeline.clock)
                            .with_context(|| format!("edge stage failed at frame {i}"));
                        let failed = staged.is_err();
                        if edge_tx.send((i, staged)).is_err() || failed {
                            return i;
                        }
                    }
                    frames.len()
                });

                let transfer = s.spawn(move || {
                    let mut shipped = 0usize;
                    while let Ok((i, staged)) = edge_rx.recv() {
                        // Forward upstream errors untouched; encode + ship
                        // the intermediate over the FIFO link otherwise.
                        // The link keeps its own timing authority (queueing
                        // + serialisation), exactly as in the 2-stage path.
                        let handoff = match staged {
                            Err(e) => Err(e),
                            Ok((intermediate, edge_t)) => match pipeline.ship(intermediate) {
                                Ok((cloud_input, xfer)) => {
                                    Ok(Some((cloud_input, edge_t, xfer)))
                                }
                                // Retry exhaustion: drop the frame, keep
                                // the stage alive for the next one.
                                Err(e) if is_transfer_abort(&e) => Ok(None),
                                Err(e) => Err(e.context(format!(
                                    "transfer stage failed at frame {i}"
                                ))),
                            },
                        };
                        let failed = handoff.is_err();
                        if link_tx.send((i, handoff)).is_err() || failed {
                            return shipped;
                        }
                        shipped = i + 1;
                    }
                    shipped
                });

                for _ in 0..frames.len() {
                    let (i, staged) = match link_rx.recv() {
                        Ok(handoff) => handoff,
                        Err(_) => break,
                    };
                    let Some((cloud_input, edge_t, xfer)) = staged? else {
                        dropped += 1;
                        continue;
                    };
                    let (output, cloud_t) = pipeline
                        .cloud_chain
                        .run(&cloud_input, &pipeline.clock)
                        .with_context(|| format!("cloud stage failed at frame {i}"))?;
                    reports.push(report(edge_t, xfer, cloud_t, output));
                }
                drop(link_rx);
                let edge_progress =
                    edge.join().map_err(|_| anyhow!("edge stage panicked"))?;
                let transfer_progress = transfer
                    .join()
                    .map_err(|_| anyhow!("transfer stage panicked"))?;
                Ok((edge_progress, transfer_progress))
            })?;

        check_complete(
            reports.len(),
            dropped,
            frames.len(),
            &[("edge", edge_progress), ("transfer", transfer_progress)],
        )?;
        Ok(reports)
    }
}

/// True when the error chain bottoms out in a [`TransferAborted`] — the
/// one failure a runner absorbs as a per-frame drop instead of a stage
/// abort (anyhow's downcast searches through the added context).
fn is_transfer_abort(e: &anyhow::Error) -> bool {
    e.downcast_ref::<TransferAborted>().is_some()
}

fn report(
    edge_t: ChainTiming,
    xfer: TransferReport,
    cloud_t: ChainTiming,
    output: Literal,
) -> InferenceReport {
    InferenceReport {
        t_edge: edge_t.total,
        t_transfer: xfer.t_transfer,
        t_cloud: cloud_t.total,
        edge_per_layer: edge_t.per_layer,
        cloud_per_layer: cloud_t.per_layer,
        t_encode: xfer.t_encode,
        t_decode: xfer.t_decode,
        raw_bytes: xfer.raw_bytes,
        wire_bytes: xfer.wire_bytes,
        codec: xfer.codec,
        transfer_attempts: xfer.attempts,
        t_backoff: xfer.t_backoff,
        output,
    }
}

/// Attribute a short run to the stage that stopped first: a hand-off
/// channel closing without a consumable error used to surface as a bare
/// "produced N of M reports" — now the message names the originating stage
/// and the frame index it stopped at. Frames the transfer stage dropped
/// on retry exhaustion are accounted for, not short.
fn check_complete(got: usize, dropped: usize, want: usize, stages: &[(&str, usize)]) -> Result<()> {
    if got + dropped == want {
        return Ok(());
    }
    let culprit = stages
        .iter()
        .min_by_key(|(_, progress)| *progress)
        .expect("at least one upstream stage");
    bail!(
        "pipelined run produced {got} of {want} reports: {} stage stopped at frame {} \
         without delivering an error",
        culprit.0,
        culprit.1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_floor_and_modes() {
        assert_eq!(PipelinedRunner::new(0).depth, 1);
        assert_eq!(PipelinedRunner::new(0).stages, StageMode::Three);
        assert_eq!(PipelinedRunner::two_stage(0).depth, 1);
        assert_eq!(PipelinedRunner::two_stage(5).stages, StageMode::Two);
        let d = PipelinedRunner::default();
        assert_eq!(d.depth, DEFAULT_DEPTH);
        assert_eq!(d.stages, StageMode::Three);
    }

    #[test]
    fn short_run_names_slowest_stage_and_frame() {
        let err = check_complete(3, 0, 8, &[("edge", 6), ("transfer", 3)]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("3 of 8"), "got: {msg}");
        assert!(msg.contains("transfer stage stopped at frame 3"), "got: {msg}");
        assert!(check_complete(8, 0, 8, &[("edge", 8)]).is_ok());
    }

    #[test]
    fn dropped_frames_are_not_a_short_run() {
        // 6 reports + 2 retry-exhaustion drops over 8 frames is complete.
        assert!(check_complete(6, 2, 8, &[("edge", 8), ("transfer", 8)]).is_ok());
        // ... but a drop cannot paper over a genuinely missing report.
        assert!(check_complete(5, 2, 8, &[("edge", 8), ("transfer", 6)]).is_err());
    }
}
