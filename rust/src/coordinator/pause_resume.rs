//! Baseline: Pause-and-Resume repartitioning (§III-A, Equation 2).
//!
//! When the network speed changes: (i) identify new metadata, (ii) pause
//! the edge-cloud pipeline (docker pause on both containers — no frames
//! are processed at all), (iii) update the metadata — the naive
//! application tears down and reloads the model on both sides (simulated
//! TF/Keras reload + the *real* PJRT recompilation of both partition
//! chains), (iv) unpause and resume. The entire window is edge service
//! downtime: `t_downtime = t_update`.

use std::sync::Arc;

use anyhow::Result;

use crate::metrics::DowntimeRecord;

use super::pipeline::{EdgeCloudEnv, Placement};
use super::router::Router;

pub struct PauseResume {
    pub env: Arc<EdgeCloudEnv>,
    pub router: Arc<Router>,
}

impl PauseResume {
    /// Deploy the initial pipeline (fresh containers on both hosts). The
    /// naive application never caches compiled executables.
    pub fn deploy(env: Arc<EdgeCloudEnv>, initial_split: usize) -> Result<Self> {
        // The naive app holds no proactive state: start from cold caches.
        env.edge.clear_cache();
        env.cloud.clear_cache();
        let p = env.build_pipeline_opts(initial_split, Placement::NewContainers, false)?;
        let router = Arc::new(Router::new(env.clock.clone(), Arc::new(p))?);
        Ok(PauseResume { env, router })
    }

    pub fn current_split(&self) -> usize {
        self.router.active().split
    }

    /// Repartition to `new_split` with Pause and Resume; returns the
    /// measured downtime record (Equation 2).
    pub fn repartition(&self, new_split: usize) -> Result<DowntimeRecord> {
        let clock = &self.env.clock;
        let sim0 = clock.simulated_component();
        let t0 = clock.now();
        let mut rec = DowntimeRecord::default();

        self.router.set_downtime(true);

        // (ii) Pause processing on the edge-cloud pipeline.
        let old = self.router.active();
        self.router.pause()?;
        self.env.edge_host.pause(&old.edge_container);
        self.env.cloud_host.pause(&old.cloud_container);
        let t_pause = clock.now() - t0;
        rec.push_phase("pause", t_pause);

        // (iii) Update metadata: the naive app reloads the DNN on both
        // sides inside the frozen containers.
        let t1 = clock.now();
        clock.sleep(self.env.cfg.costs.baseline_reload);
        // The naive application tears its whole model down: invalidate any
        // compiled executables and staged weight buffers on both domains,
        // then rebuild with use_cache = false (the paper's full Keras
        // reload, not just the split delta). This keeps the ablation
        // against Dynamic Switching's warm caches meaningful.
        self.env.edge.clear_cache();
        self.env.cloud.clear_cache();
        let new_pipe = self.env.build_pipeline_opts(
            new_split,
            Placement::Existing {
                edge: old.edge_container.clone(),
                cloud: old.cloud_container.clone(),
            },
            false,
        )?;
        rec.push_phase("update", clock.now() - t1);

        // (iv) Resume execution with the new partitions.
        let t2 = clock.now();
        self.env.edge_host.unpause(&old.edge_container);
        self.env.cloud_host.unpause(&old.cloud_container);
        self.router.resume(Some(Arc::new(new_pipe)))?;
        rec.push_phase("resume", clock.now() - t2);

        self.router.set_downtime(false);
        rec.total = clock.now() - t0;
        rec.simulated = clock.simulated_component() - sim0;
        Ok(rec)
    }
}
