//! Frame micro-batcher: the bounded queue in front of the edge stage.
//!
//! The AOT executables are compiled for batch-1 video frames (the paper's
//! workload), so "batching" here is admission + drain policy rather than
//! tensor batching: frames queue up to a capacity, the serving loop drains
//! up to `drain_max` per wake (amortising scheduling overhead), and
//! arrivals beyond capacity are dropped — the edge behaviour behind
//! Figs 14/15.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::device::Frame;
use crate::util::sync::{lock_clean, wait_timeout_while_clean};

/// Result of offering a frame to the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Offer {
    Accepted,
    /// Queue full — frame dropped at the edge.
    Rejected,
}

pub struct Batcher {
    inner: Mutex<VecDeque<Frame>>,
    notify: Condvar,
    pub capacity: usize,
    pub drain_max: usize,
}

impl Batcher {
    pub fn new(capacity: usize, drain_max: usize) -> Self {
        assert!(capacity > 0 && drain_max > 0);
        Batcher {
            inner: Mutex::new(VecDeque::with_capacity(capacity)),
            notify: Condvar::new(),
            capacity,
            drain_max,
        }
    }

    /// Non-blocking enqueue; full queue rejects (frame drop).
    pub fn offer(&self, frame: Frame) -> Offer {
        let mut q = lock_clean(&self.inner);
        if q.len() >= self.capacity {
            return Offer::Rejected;
        }
        q.push_back(frame);
        self.notify.notify_one();
        Offer::Accepted
    }

    /// Drain up to `drain_max` queued frames (non-blocking).
    pub fn drain(&self) -> Vec<Frame> {
        let mut q = lock_clean(&self.inner);
        let n = q.len().min(self.drain_max);
        q.drain(..n).collect()
    }

    /// Blocking drain: waits until at least one frame is available or the
    /// timeout elapses. Returns an empty vec on timeout.
    pub fn drain_wait(&self, timeout: std::time::Duration) -> Vec<Frame> {
        let q = lock_clean(&self.inner);
        let (mut q, _t) =
            wait_timeout_while_clean(&self.notify, q, timeout, |q| q.is_empty());
        let n = q.len().min(self.drain_max);
        q.drain(..n).collect()
    }

    pub fn len(&self) -> usize {
        lock_clean(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(id: u64) -> Frame {
        Frame {
            id,
            captured_at: std::time::Duration::ZERO,
            pixels: vec![0.0; 4],
            shape: vec![1, 1, 1, 4],
        }
    }

    #[test]
    fn accepts_until_capacity() {
        let b = Batcher::new(2, 4);
        assert_eq!(b.offer(frame(0)), Offer::Accepted);
        assert_eq!(b.offer(frame(1)), Offer::Accepted);
        assert_eq!(b.offer(frame(2)), Offer::Rejected);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn drain_respects_max_and_order() {
        let b = Batcher::new(8, 2);
        for i in 0..5 {
            b.offer(frame(i));
        }
        let d = b.drain();
        assert_eq!(d.iter().map(|f| f.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn drain_empty_is_empty() {
        let b = Batcher::new(2, 2);
        assert!(b.drain().is_empty());
        assert!(b.is_empty());
    }

    #[test]
    fn freed_capacity_accepts_again() {
        let b = Batcher::new(1, 1);
        b.offer(frame(0));
        assert_eq!(b.offer(frame(1)), Offer::Rejected);
        b.drain();
        assert_eq!(b.offer(frame(2)), Offer::Accepted);
    }

    #[test]
    fn drain_wait_times_out() {
        let b = Batcher::new(2, 2);
        let got = b.drain_wait(std::time::Duration::from_millis(10));
        assert!(got.is_empty());
    }

    #[test]
    fn drain_wait_wakes_on_offer() {
        use std::sync::Arc;
        let b = Arc::new(Batcher::new(2, 2));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.drain_wait(std::time::Duration::from_secs(5)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        b.offer(frame(7));
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 7);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_capacity() {
        Batcher::new(0, 1);
    }
}
