//! Experiment drivers: one function per paper figure/table.
//!
//! Each driver runs the relevant approach on a simulated-clock environment
//! with real PJRT work and returns structured rows; the bench binaries and
//! `examples/reproduce_all.rs` render them as paper-vs-measured tables.
//! See DESIGN.md §Experiment index for the mapping.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::clock::Clock;
use crate::config::ExperimentConfig;
use crate::metrics::DowntimeRecord;
use crate::models::{default_artifacts_dir, ArtifactIndex, ModelManifest};
use crate::profiler::{self, ModelProfile};
use crate::stress::{self, StressProfile};

use super::flow::{simulate_window, FlowOutcome};
use super::pause_resume::PauseResume;
use super::pipeline::EdgeCloudEnv;
use super::switching::{PlacementCase, ScenarioA, ScenarioB};

/// Shared setup for all experiment drivers.
pub struct ExperimentSetup {
    pub cfg: ExperimentConfig,
    pub index: ArtifactIndex,
}

impl ExperimentSetup {
    /// Load artifacts from the default location.
    pub fn load() -> Result<Self> {
        let index = ArtifactIndex::load(default_artifacts_dir())?;
        Ok(ExperimentSetup { cfg: ExperimentConfig::new(), index })
    }

    pub fn with_cfg(mut self, cfg: ExperimentConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn manifest(&self, model: &str) -> Result<ModelManifest> {
        self.index.model(model)
    }

    /// Simulated-clock environment for sweep experiments.
    pub fn env(&self, model: &str) -> Result<Arc<EdgeCloudEnv>> {
        let manifest = self.manifest(model)?;
        Ok(Arc::new(EdgeCloudEnv::new(
            self.cfg.clone(),
            manifest,
            Clock::simulated(),
        )?))
    }

    /// Measure the per-layer profile on a fresh env (used by Fig 2/3 and
    /// to derive the high/low split points for the downtime experiments).
    pub fn measured_profile(&self, env: &EdgeCloudEnv, reps: usize) -> Result<ModelProfile> {
        profiler::measure(
            &env.manifest,
            &env.weights,
            env.edge.clone(),
            env.cloud.clone(),
            reps,
        )
    }
}

/// The two split points every repartition experiment toggles between.
#[derive(Debug, Clone, Copy)]
pub struct SplitPair {
    pub at_high: usize,
    pub at_low: usize,
}

pub fn split_pair(profile: &ModelProfile, cfg: &ExperimentConfig) -> SplitPair {
    SplitPair {
        at_high: profile.optimal_split(cfg.network.high_mbps, cfg.network.latency, 1.0),
        at_low: profile.optimal_split(cfg.network.low_mbps, cfg.network.latency, 1.0),
    }
}

// ---------------------------------------------------------------------------
// Fig 2 / Fig 3: partition sweep
// ---------------------------------------------------------------------------

/// One stacked bar of Fig 2/3.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub split: usize,
    pub layer: String,
    pub edge_s: f64,
    pub transfer_s: f64,
    pub cloud_s: f64,
    pub total_s: f64,
    pub out_kb: f64,
    pub optimal: bool,
}

/// All split points of `profile` at `bandwidth` (one panel of Fig 2/3).
pub fn partition_sweep(
    profile: &ModelProfile,
    bandwidth_mbps: f64,
    latency: Duration,
) -> Vec<SweepRow> {
    let opt = profile.optimal_split(bandwidth_mbps, latency, 1.0);
    profile
        .sweep(bandwidth_mbps, latency, 1.0)
        .into_iter()
        .map(|b| {
            let bytes = if b.split == 0 {
                profile.input_bytes
            } else {
                profile.layers[b.split - 1].output_bytes
            };
            SweepRow {
                split: b.split,
                layer: if b.split == 0 {
                    "input".to_string()
                } else {
                    profile.layers[b.split - 1].name.clone()
                },
                edge_s: b.edge.as_secs_f64(),
                transfer_s: b.transfer.as_secs_f64(),
                cloud_s: b.cloud.as_secs_f64(),
                total_s: b.total().as_secs_f64(),
                out_kb: bytes as f64 / 1024.0,
                optimal: b.split == opt,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Fig 11/12/13: downtime grids
// ---------------------------------------------------------------------------

/// The approach under test in a downtime grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Approach {
    PauseResume,
    ScenarioA(PlacementCase),
    ScenarioB(PlacementCase),
}

impl Approach {
    pub fn label(&self) -> &'static str {
        match self {
            Approach::PauseResume => "pause-resume",
            Approach::ScenarioA(PlacementCase::NewContainer) => "scenario-a-case1",
            Approach::ScenarioA(PlacementCase::SameContainer) => "scenario-a-case2",
            Approach::ScenarioB(PlacementCase::NewContainer) => "scenario-b-case1",
            Approach::ScenarioB(PlacementCase::SameContainer) => "scenario-b-case2",
        }
    }
}

/// One cell of a Fig 11/12/13 surface.
#[derive(Debug, Clone)]
pub struct GridCell {
    pub cpu_avail: f64,
    pub mem_avail: f64,
    /// None = the pipeline could not be admitted (the paper's missing
    /// 10 %-memory results).
    pub downtime: Option<DowntimeRecord>,
}

/// Run one repartition of `approach` on `env` under `stress_profile`,
/// switching from the optimal split at `from_mbps` to the optimal at
/// `to_mbps`. Returns None on admission failure (OOM).
pub fn measure_downtime(
    env: &Arc<EdgeCloudEnv>,
    profile: &ModelProfile,
    approach: Approach,
    stress_profile: StressProfile,
    from_mbps: f64,
    to_mbps: f64,
) -> Result<Option<DowntimeRecord>> {
    // Apply stress: memory hog + CPU dilation on the edge.
    let base_scale = env.cfg.compute.edge_scale;
    let _applied = match stress::apply(&env.edge_host.ledger, stress_profile) {
        Ok(a) => a,
        Err(_) => return Ok(None), // stressor itself cannot even start
    };
    env.edge.set_cpu_scale(stress_profile.edge_scale(base_scale));
    let _restore = ScopeGuard(|| env.edge.set_cpu_scale(base_scale));

    env.link.set_bandwidth(from_mbps);
    let lat = env.cfg.network.latency;
    let from_split = profile.optimal_split(from_mbps, lat, 1.0);
    let to_split = profile.optimal_split(to_mbps, lat, 1.0);

    let run = || -> Result<DowntimeRecord> {
        match approach {
            Approach::PauseResume => {
                let strat = PauseResume::deploy(env.clone(), from_split)?;
                env.link.set_bandwidth(to_mbps);
                strat.repartition(to_split)
            }
            Approach::ScenarioA(case) => {
                let strat = ScenarioA::deploy(env.clone(), from_split, to_split, case)?;
                env.link.set_bandwidth(to_mbps);
                strat.switch()
            }
            Approach::ScenarioB(case) => {
                let strat = ScenarioB::deploy(env.clone(), from_split)?.with_case(case);
                env.link.set_bandwidth(to_mbps);
                strat.repartition(to_split)
            }
        }
    };
    match run() {
        Ok(rec) => Ok(Some(rec)),
        Err(e) => {
            // Admission failures (OOM) are expected at low memory; anything
            // else is a real error.
            if e.to_string().contains("OOM") || e.chain().any(|c| c.to_string().contains("OOM")) {
                Ok(None)
            } else {
                Err(e).context("downtime measurement failed")
            }
        }
    }
}

/// Full CPU x memory grid for one approach and direction (a Fig 11/12/13
/// panel).
pub fn downtime_grid(
    env: &Arc<EdgeCloudEnv>,
    profile: &ModelProfile,
    approach: Approach,
    from_mbps: f64,
    to_mbps: f64,
) -> Result<Vec<GridCell>> {
    let mut cells = Vec::new();
    for sp in StressProfile::paper_grid() {
        let downtime = measure_downtime(env, profile, approach, sp, from_mbps, to_mbps)?;
        cells.push(GridCell { cpu_avail: sp.cpu_avail, mem_avail: sp.mem_avail, downtime });
    }
    Ok(cells)
}

// ---------------------------------------------------------------------------
// Fig 14/15: frame drop during downtime
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct FrameDropRow {
    pub approach: &'static str,
    pub fps: f64,
    pub downtime_s: f64,
    pub outcome: FlowOutcome,
}

/// Frame-drop rates during the downtime of `approach` at `bandwidth`:
/// the baseline serves nothing; Dynamic Switching keeps serving on the old
/// pipeline whose degraded per-frame service time comes from Eq 1 at the
/// *new* bandwidth with the *old* split.
pub fn frame_drop_rows(
    profile: &ModelProfile,
    cfg: &ExperimentConfig,
    approach: Approach,
    downtime: Duration,
    from_mbps: f64,
    to_mbps: f64,
    fps_list: &[f64],
) -> Vec<FrameDropRow> {
    let lat = cfg.network.latency;
    let old_split = profile.optimal_split(from_mbps, lat, 1.0);
    let service = match approach {
        Approach::PauseResume => None,
        _ => {
            // The edge stage holds a frame for its edge compute + uplink
            // serialisation at the degraded bandwidth.
            let b = profile.breakdown(old_split, to_mbps, lat, 1.0);
            Some(b.edge + b.transfer)
        }
    };
    fps_list
        .iter()
        .map(|&fps| FrameDropRow {
            approach: approach.label(),
            fps,
            downtime_s: downtime.as_secs_f64(),
            outcome: simulate_window(downtime, fps, service, cfg.queue_capacity),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Transfer-codec comparison
// ---------------------------------------------------------------------------

/// One row of the codec comparison: what a given codec does to the planned
/// split and the Equation-1 prediction at one bandwidth.
#[derive(Debug, Clone)]
pub struct CodecRow {
    pub codec: crate::codec::TransferCodec,
    pub bandwidth_mbps: f64,
    pub split: usize,
    /// Encoded bytes crossing the link at the planned split.
    pub wire_bytes: usize,
    /// Raw-to-wire ratio at the planned split.
    pub compression: f64,
    pub t_transfer_s: f64,
    pub total_s: f64,
}

/// Plan every codec at the config's low and high bandwidths. Shows the
/// memory-vs-downtime story of the codec knob: quantised transfers shrink
/// `T_t`, which both lowers the predicted frame latency and moves the
/// optimum split (usually earlier, shifting compute to the cloud).
pub fn codec_comparison(
    profile: &ModelProfile,
    cfg: &ExperimentConfig,
    codecs: &[crate::codec::TransferCodec],
) -> Vec<CodecRow> {
    let mut rows = Vec::new();
    for &bw in &[cfg.network.low_mbps, cfg.network.high_mbps] {
        for &codec in codecs {
            let planner = super::planner::Planner::new(profile.clone(), cfg.network.latency)
                .with_codec(codec);
            let plan = planner.plan(bw);
            let raw = profile.cut_bytes(plan.split);
            let wire = codec.encoded_bytes(raw);
            rows.push(CodecRow {
                codec,
                bandwidth_mbps: bw,
                split: plan.split,
                wire_bytes: wire,
                compression: if wire == 0 { 1.0 } else { raw as f64 / wire as f64 },
                t_transfer_s: plan.predicted.transfer.as_secs_f64(),
                total_s: plan.predicted.total().as_secs_f64(),
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// Table I: memory accounting
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MemoryRow {
    pub approach: &'static str,
    pub initial_mb: f64,
    pub additional_mb: f64,
    pub peak_mb: f64,
    pub transient: bool,
}

/// Measure the edge-ledger footprint of each approach (Table I). Uses a
/// fresh env per approach so ledgers start clean.
pub fn table1_memory(setup: &ExperimentSetup, model: &str) -> Result<Vec<MemoryRow>> {
    let mut rows = Vec::new();
    let cfg = &setup.cfg;
    let lat = cfg.network.latency;

    for approach in [
        Approach::PauseResume,
        Approach::ScenarioA(PlacementCase::NewContainer),
        Approach::ScenarioA(PlacementCase::SameContainer),
        Approach::ScenarioB(PlacementCase::NewContainer),
        Approach::ScenarioB(PlacementCase::SameContainer),
    ] {
        let env = setup.env(model)?;
        let profile = crate::profiler::default_analytic(&env.manifest);
        let from_split = profile.optimal_split(cfg.network.high_mbps, lat, 1.0);
        let to_split = profile.optimal_split(cfg.network.low_mbps, lat, 1.0);

        let pipelines_mb = |env: &EdgeCloudEnv| -> f64 {
            env.edge_host
                .ledger
                .entries()
                .iter()
                .filter(|(l, _)| l.starts_with("container:"))
                .map(|(_, m)| m)
                .sum()
        };

        let (initial, peak_raw) = match approach {
            Approach::PauseResume => {
                let strat = PauseResume::deploy(env.clone(), from_split)?;
                let initial = pipelines_mb(&env);
                env.edge_host.ledger.reset_peak();
                env.link.set_bandwidth(cfg.network.low_mbps);
                strat.repartition(to_split)?;
                (initial, env.edge_host.ledger.peak_mb())
            }
            Approach::ScenarioA(case) => {
                let strat = ScenarioA::deploy(env.clone(), from_split, to_split, case)?;
                let initial = pipelines_mb(&env);
                env.edge_host.ledger.reset_peak();
                env.link.set_bandwidth(cfg.network.low_mbps);
                strat.switch()?;
                (initial, env.edge_host.ledger.peak_mb())
            }
            Approach::ScenarioB(case) => {
                let strat = ScenarioB::deploy(env.clone(), from_split)?.with_case(case);
                let initial = pipelines_mb(&env);
                env.edge_host.ledger.reset_peak();
                env.link.set_bandwidth(cfg.network.low_mbps);
                strat.repartition(to_split)?;
                (initial, env.edge_host.ledger.peak_mb())
            }
        };
        // Peak includes the OS overhead + stress entries; report the
        // pipeline-attributable part.
        let overhead = cfg.memory.os_overhead_mb;
        let peak = (peak_raw - overhead).max(0.0);
        let additional = (peak - initial).max(0.0);
        let settled = pipelines_mb(&env);
        rows.push(MemoryRow {
            approach: approach.label(),
            initial_mb: initial,
            additional_mb: additional,
            peak_mb: peak,
            transient: additional > 0.0 && settled <= initial + 1e-9,
        });
    }
    Ok(rows)
}

/// Tiny scope guard (no external crates).
struct ScopeGuard<F: FnMut()>(F);

impl<F: FnMut()> Drop for ScopeGuard<F> {
    fn drop(&mut self) {
        (self.0)();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::LayerProfile;

    fn profile() -> ModelProfile {
        let layers = (0..6)
            .map(|i| LayerProfile {
                index: i,
                name: format!("l{i}"),
                kind: "conv".into(),
                edge_time: Duration::from_millis(10),
                cloud_time: Duration::from_millis(2),
                output_bytes: 400_000 >> i,
                ..Default::default()
            })
            .collect();
        ModelProfile { model: "toy".into(), input_bytes: 800_000, layers }
    }

    #[test]
    fn sweep_marks_exactly_one_optimum() {
        let rows = partition_sweep(&profile(), 20.0, Duration::from_millis(20));
        assert_eq!(rows.len(), 7);
        assert_eq!(rows.iter().filter(|r| r.optimal).count(), 1);
        let opt = rows.iter().find(|r| r.optimal).unwrap();
        for r in &rows {
            assert!(opt.total_s <= r.total_s + 1e-12);
        }
    }

    #[test]
    fn split_pair_moves_with_bandwidth() {
        let cfg = ExperimentConfig::new();
        let p = split_pair(&profile(), &cfg);
        assert!(p.at_low >= p.at_high);
    }

    #[test]
    fn frame_drop_baseline_worst() {
        let cfg = ExperimentConfig::new();
        let p = profile();
        let dt = Duration::from_secs(6);
        let base =
            frame_drop_rows(&p, &cfg, Approach::PauseResume, dt, 20.0, 5.0, &[30.0]);
        let dyn_b = frame_drop_rows(
            &p,
            &cfg,
            Approach::ScenarioB(PlacementCase::SameContainer),
            Duration::from_millis(600),
            20.0,
            5.0,
            &[30.0],
        );
        assert!(base[0].outcome.dropped > dyn_b[0].outcome.dropped);
    }

    #[test]
    fn codec_comparison_rewards_quantised_transfers() {
        use crate::codec::TransferCodec;
        let cfg = ExperimentConfig::new();
        let codecs = [TransferCodec::Fp32, TransferCodec::Fp16, TransferCodec::Int8];
        let rows = codec_comparison(&profile(), &cfg, &codecs);
        assert_eq!(rows.len(), 6); // 2 bandwidths x 3 codecs
        let at = |bw: f64, c: TransferCodec| {
            rows.iter()
                .find(|r| r.bandwidth_mbps == bw && r.codec == c)
                .unwrap()
        };
        let low = cfg.network.low_mbps;
        let fp32 = at(low, TransferCodec::Fp32);
        let int8 = at(low, TransferCodec::Int8);
        // At its own optimum, the quantised plan beats shipping raw fp32
        // end to end, and its compression reflects the 4x + header model.
        assert!(int8.total_s < fp32.total_s);
        assert!((fp32.compression - 1.0).abs() < 1e-12);
        assert!(int8.compression > 3.0);
    }

    #[test]
    fn approach_labels_unique() {
        let labels: Vec<_> = [
            Approach::PauseResume,
            Approach::ScenarioA(PlacementCase::NewContainer),
            Approach::ScenarioA(PlacementCase::SameContainer),
            Approach::ScenarioB(PlacementCase::NewContainer),
            Approach::ScenarioB(PlacementCase::SameContainer),
        ]
        .iter()
        .map(|a| a.label())
        .collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels.len(), 5);
        assert_eq!(dedup.len(), 5);
    }
}
