//! Pipeline lifecycle state machine.
//!
//! Transitions are validated: an illegal transition is a coordinator bug
//! and fails loudly rather than silently corrupting an experiment.

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineState {
    /// Containers starting / chains compiling.
    Initialising,
    /// Built and warm, not receiving traffic (Scenario A standby).
    Standby,
    /// Receiving routed traffic.
    Active,
    /// Paused by the baseline approach (no traffic processed).
    Paused,
    /// Being replaced; drains in-flight work.
    Draining,
    /// Stopped; resources released.
    Terminated,
}

impl fmt::Display for PipelineState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PipelineState::Initialising => "initialising",
            PipelineState::Standby => "standby",
            PipelineState::Active => "active",
            PipelineState::Paused => "paused",
            PipelineState::Draining => "draining",
            PipelineState::Terminated => "terminated",
        };
        f.write_str(s)
    }
}

impl PipelineState {
    /// Whether `self -> to` is a legal lifecycle transition.
    pub fn can_transition(self, to: PipelineState) -> bool {
        use PipelineState::*;
        matches!(
            (self, to),
            (Initialising, Standby)
                | (Initialising, Active)
                | (Standby, Active)
                | (Active, Paused)
                | (Paused, Active)
                | (Active, Draining)
                | (Active, Standby)
                | (Draining, Standby)
                | (Draining, Terminated)
                | (Standby, Terminated)
                | (Paused, Terminated)
                // Stillborn: built but never served — a probe-guarded
                // switch rolled back before activation.
                | (Initialising, Terminated)
        )
    }

    /// Can this pipeline process a frame right now?
    pub fn serves_traffic(self) -> bool {
        matches!(self, PipelineState::Active | PipelineState::Draining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use PipelineState::*;

    #[test]
    fn legal_lifecycle_paths() {
        // Baseline: active -> paused -> active.
        assert!(Active.can_transition(Paused));
        assert!(Paused.can_transition(Active));
        // Dynamic switching: init -> standby -> active -> draining -> term.
        assert!(Initialising.can_transition(Standby));
        assert!(Standby.can_transition(Active));
        assert!(Active.can_transition(Draining));
        assert!(Draining.can_transition(Terminated));
        // Scenario A swap: old active pipeline becomes the new standby.
        assert!(Active.can_transition(Standby));
        // Rollback: a stillborn pipeline (probe failed before activation)
        // is retired without ever serving.
        assert!(Initialising.can_transition(Terminated));
    }

    #[test]
    fn illegal_transitions_rejected() {
        assert!(!Terminated.can_transition(Active));
        assert!(!Paused.can_transition(Standby));
        assert!(!Initialising.can_transition(Paused));
        assert!(!Standby.can_transition(Paused));
        assert!(!Terminated.can_transition(Initialising));
    }

    #[test]
    fn traffic_gating() {
        assert!(Active.serves_traffic());
        assert!(Draining.serves_traffic());
        assert!(!Paused.serves_traffic());
        assert!(!Standby.serves_traffic());
        assert!(!Initialising.serves_traffic());
        assert!(!Terminated.serves_traffic());
    }

    #[test]
    fn display_names() {
        assert_eq!(Active.to_string(), "active");
        assert_eq!(Initialising.to_string(), "initialising");
    }
}
