//! The serving daemon: threads wiring device -> batcher -> router ->
//! pipeline, with the monitor/planner control loop driving repartitions.
//!
//! This is the deployable form of the system (the e2e example and the
//! `serve` CLI subcommand are thin wrappers around it): a camera thread
//! paces frames into the bounded [`Batcher`]; a worker drains and routes
//! them; a control thread polls the [`NetworkMonitor`] through the
//! [`TriggerPolicy`] and executes the configured repartition strategy.
//! Everything shuts down cleanly on `stop()` or when the trace ends.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::device::FrameSource;
use crate::metrics::DowntimeRecord;
use crate::util::sync::lock_clean;

use super::batcher::{Batcher, Offer};
use super::monitor::{NetworkMonitor, TriggerPolicy};
use super::pause_resume::PauseResume;
use super::pipeline::EdgeCloudEnv;
use super::planner::Planner;
use super::router::Router;
use super::switching::{PlacementCase, ScenarioA, ScenarioB};

/// Which repartitioning strategy the server runs.
pub enum Strategy {
    PauseResume(PauseResume),
    ScenarioA(ScenarioA),
    ScenarioB(ScenarioB),
}

impl Strategy {
    pub fn router(&self) -> Arc<Router> {
        match self {
            Strategy::PauseResume(s) => s.router.clone(),
            Strategy::ScenarioA(s) => s.router.clone(),
            Strategy::ScenarioB(s) => s.router.clone(),
        }
    }

    /// Execute one repartition to `split`, returning the downtime record.
    pub fn repartition(&self, split: usize) -> Result<DowntimeRecord> {
        match self {
            Strategy::PauseResume(s) => s.repartition(split),
            Strategy::ScenarioA(s) => {
                let rec = s.switch()?;
                // Background: make sure the displaced standby matches the
                // next plan if the toggle is not symmetric.
                let _ = s.ensure_standby(split_of(&s.router));
                Ok(rec)
            }
            Strategy::ScenarioB(s) => s.repartition(split),
        }
    }

    /// Deploy by name ("pause-resume", "scenario-a-case1", ...).
    pub fn deploy(
        name: &str,
        env: Arc<EdgeCloudEnv>,
        initial_split: usize,
        standby_split: usize,
    ) -> Result<Strategy> {
        Ok(match name {
            "pause-resume" => Strategy::PauseResume(PauseResume::deploy(env, initial_split)?),
            "scenario-a-case1" => Strategy::ScenarioA(ScenarioA::deploy(
                env,
                initial_split,
                standby_split,
                PlacementCase::NewContainer,
            )?),
            "scenario-a-case2" => Strategy::ScenarioA(ScenarioA::deploy(
                env,
                initial_split,
                standby_split,
                PlacementCase::SameContainer,
            )?),
            "scenario-b-case1" => Strategy::ScenarioB(
                ScenarioB::deploy(env, initial_split)?.with_case(PlacementCase::NewContainer),
            ),
            "scenario-b-case2" => Strategy::ScenarioB(
                ScenarioB::deploy(env, initial_split)?.with_case(PlacementCase::SameContainer),
            ),
            other => anyhow::bail!("unknown strategy {other:?}"),
        })
    }
}

fn split_of(router: &Arc<Router>) -> usize {
    router.active().split
}

/// Server configuration.
pub struct ServerConfig {
    pub fps: f64,
    pub run_for: Duration,
    pub queue_capacity: usize,
    pub drain_max: usize,
    pub policy: TriggerPolicy,
    /// Monitor poll interval.
    pub poll_every: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            fps: 15.0,
            run_for: Duration::from_secs(15),
            queue_capacity: 8,
            drain_max: 4,
            policy: TriggerPolicy::immediate(),
            poll_every: Duration::from_millis(100),
        }
    }
}

/// Outcome of a serve run.
#[derive(Debug, Default)]
pub struct ServeReport {
    pub downtimes: Vec<DowntimeRecord>,
    pub repartitions: Vec<(f64, usize)>, // (new bandwidth, new split)
    pub elapsed: Duration,
}

/// Run the serving loop to completion (blocking; realtime clock expected,
/// but a simulated clock also works for tests — sleeps become offsets).
pub fn serve(
    strategy: &Strategy,
    env: &Arc<EdgeCloudEnv>,
    monitor: &NetworkMonitor,
    planner: &Planner,
    cfg: ServerConfig,
) -> Result<ServeReport> {
    let router = strategy.router();
    let batcher = Arc::new(Batcher::new(cfg.queue_capacity, cfg.drain_max));
    let stop = Arc::new(AtomicBool::new(false));
    let clock = env.clock.clone();
    let started = clock.now();
    let report = Arc::new(Mutex::new(ServeReport::default()));
    // The PJRT handles inside Router/Pipeline are not Send, so the camera
    // thread counts into plain atomics that are reconciled into the
    // router's stats afterwards. `in_downtime` mirrors the router flag for
    // drop attribution.
    let produced = Arc::new(AtomicU64::new(0));
    let rejected = Arc::new(AtomicU64::new(0));
    let rejected_dt = Arc::new(AtomicU64::new(0));
    let in_downtime = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| -> Result<()> {
        // Camera thread: paces frames into the batcher; full queue = drop.
        {
            let batcher = batcher.clone();
            let stop = stop.clone();
            let clock = clock.clone();
            let input_shape = env.manifest.input_shape.clone();
            let fps = cfg.fps;
            let run_for = cfg.run_for;
            let seed = env.cfg.seed;
            let produced = produced.clone();
            let rejected = rejected.clone();
            let rejected_dt = rejected_dt.clone();
            let in_downtime = in_downtime.clone();
            scope.spawn(move || {
                let mut cam = FrameSource::new(&input_shape, fps, seed);
                while !stop.load(Ordering::Acquire) && clock.now() - started < run_for {
                    let frame = cam.next_frame();
                    let due = frame.captured_at + cam.interval();
                    produced.fetch_add(1, Ordering::Relaxed);
                    if batcher.offer(frame) == Offer::Rejected {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        if in_downtime.load(Ordering::Acquire) {
                            rejected_dt.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let now = clock.now() - started;
                    if due > now {
                        // Real pacing wait even when the clock is simulated:
                        // a simulated sleep would advance the timeline and
                        // stampede every pending frame due at once.
                        // neukonfig_lint: allow(raw_sleep) — camera pacing is wall-time by design
                        std::thread::sleep((due - now).min(Duration::from_millis(200)));
                    }
                }
                stop.store(true, Ordering::Release);
            });
        }

        // Serving + control loop (this thread — the PJRT client and its
        // executables are not Send, so ALL inference stays here; the
        // camera thread only produces plain frame data).
        while !stop.load(Ordering::Acquire) && clock.now() - started < cfg.run_for {
            // Control: monitor -> policy -> planner -> strategy.
            let now = clock.now() - started;
            let observed = monitor.poll(now);
            if let Some(change) = cfg.policy.filter(now, observed) {
                let current = router.active().split;
                if let Some(plan) = planner.should_repartition(current, change.to_mbps) {
                    in_downtime.store(true, Ordering::Release);
                    let rec = strategy.repartition(plan.split)?;
                    in_downtime.store(false, Ordering::Release);
                    let mut r = lock_clean(&report);
                    r.downtimes.push(rec);
                    r.repartitions.push((change.to_mbps, plan.split));
                }
            }

            // Serve: drain up to drain_max queued frames.
            let frames = batcher.drain_wait(cfg.poll_every);
            for frame in frames {
                let Ok(lit) = env.frame_literal(&frame) else { continue };
                if router.is_paused() {
                    router.stats.dropped(router.in_downtime());
                    continue;
                }
                match router.active().infer(&lit) {
                    Ok(rep) => {
                        router.latency.record(rep.total());
                        router.stats.processed();
                    }
                    Err(_) => router.stats.dropped(router.in_downtime()),
                }
            }
        }
        stop.store(true, Ordering::Release);
        Ok(())
    })?;

    // Reconcile the camera thread's counters into the router stats.
    for _ in 0..produced.load(Ordering::Relaxed) {
        router.stats.produced();
    }
    let dt = rejected_dt.load(Ordering::Relaxed);
    for i in 0..rejected.load(Ordering::Relaxed) {
        router.stats.dropped(i < dt);
    }

    let mut r = Arc::try_unwrap(report)
        .map_err(|_| anyhow::anyhow!("report still shared"))?
        .into_inner()
        .unwrap();
    r.elapsed = clock.now() - started;
    Ok(r)
}
