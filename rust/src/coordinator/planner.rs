//! Repartition planner: turns a bandwidth change into new partition
//! metadata (the split point), using the Equation-1 profile.
//!
//! §III-A step (i): "identify the new metadata ... using an estimation-
//! based approach to predict the latency of individual layers" — our
//! profile is measured per layer once (or analytic from FLOPs) and the
//! planner evaluates Eq. 1 across all split points in microseconds.

use std::time::Duration;

use crate::codec::TransferCodec;
use crate::profiler::{LatencyBreakdown, ModelProfile};

/// New partition metadata for a pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionPlan {
    pub split: usize,
    pub predicted: LatencyBreakdown,
}

pub struct Planner {
    profile: ModelProfile,
    latency: Duration,
    edge_cpu_avail: f64,
    /// Transfer codec the pipelines will ship with — the Equation-1
    /// transfer term must be costed at *encoded* bytes or the planner
    /// optimises a payload nobody sends.
    codec: TransferCodec,
}

impl Planner {
    pub fn new(profile: ModelProfile, latency: Duration) -> Self {
        Planner {
            profile,
            latency,
            edge_cpu_avail: 1.0,
            codec: TransferCodec::from_env(),
        }
    }

    pub fn with_cpu_avail(mut self, avail: f64) -> Self {
        self.edge_cpu_avail = avail;
        self
    }

    /// Plan against a specific transfer codec (overrides the env default).
    pub fn with_codec(mut self, codec: TransferCodec) -> Self {
        self.codec = codec;
        self
    }

    /// Optimal split for the given bandwidth.
    pub fn plan(&self, bandwidth_mbps: f64) -> PartitionPlan {
        let split = self.profile.optimal_split_coded(
            bandwidth_mbps,
            self.latency,
            self.edge_cpu_avail,
            self.codec,
        );
        PartitionPlan {
            split,
            predicted: self.profile.breakdown_coded(
                split,
                bandwidth_mbps,
                self.latency,
                self.edge_cpu_avail,
                self.codec,
            ),
        }
    }

    /// Whether a bandwidth change actually moves the split (if not, no
    /// repartition is needed — the future-work point of §VI).
    pub fn should_repartition(&self, current_split: usize, new_bw: f64) -> Option<PartitionPlan> {
        let plan = self.plan(new_bw);
        (plan.split != current_split).then_some(plan)
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    pub fn latency(&self) -> Duration {
        self.latency
    }

    pub fn codec(&self) -> TransferCodec {
        self.codec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::LayerProfile;

    fn profile() -> ModelProfile {
        // Compute-heavy early layers with shrinking outputs.
        let layers = (0..8)
            .map(|i| LayerProfile {
                index: i,
                name: format!("l{i}"),
                kind: "conv".into(),
                edge_time: Duration::from_millis(20),
                cloud_time: Duration::from_millis(4),
                output_bytes: 800_000 >> i,
                ..Default::default()
            })
            .collect();
        ModelProfile { model: "toy".into(), input_bytes: 1_600_000, layers }
    }

    #[test]
    fn plan_matches_profile_optimum() {
        let p = Planner::new(profile(), Duration::from_millis(20));
        let plan = p.plan(20.0);
        assert_eq!(
            plan.split,
            p.profile().optimal_split(20.0, Duration::from_millis(20), 1.0)
        );
        assert_eq!(plan.predicted.split, plan.split);
    }

    #[test]
    fn no_repartition_when_split_unchanged() {
        let p = Planner::new(profile(), Duration::from_millis(20));
        let plan = p.plan(20.0);
        assert!(p.should_repartition(plan.split, 20.0).is_none());
    }

    #[test]
    fn bandwidth_drop_changes_plan() {
        let p = Planner::new(profile(), Duration::from_millis(20));
        let high = p.plan(100.0);
        let low = p.plan(0.5);
        assert!(low.split >= high.split, "{} >= {}", low.split, high.split);
        assert!(p.should_repartition(high.split, 0.5).is_some());
    }

    #[test]
    fn codec_choice_moves_the_planned_split() {
        // At 5 Mbps the fp32 planner hides deep in the network to shrink
        // the payload; quartered int8 transfers let it cut earlier and
        // lean on the 5x faster cloud. (We assert direction, not the exact
        // int8 split — two splits tie to within Duration rounding.)
        let lat = Duration::from_millis(20);
        let fp32 = Planner::new(profile(), lat).with_codec(TransferCodec::Fp32);
        let int8 = Planner::new(profile(), lat).with_codec(TransferCodec::Int8);
        assert_eq!(int8.codec(), TransferCodec::Int8);
        let fp32_plan = fp32.plan(5.0);
        let int8_plan = int8.plan(5.0);
        assert!(
            int8_plan.split < fp32_plan.split,
            "int8 split {} should be earlier than fp32 split {}",
            int8_plan.split,
            fp32_plan.split
        );
        // Switching codecs at the same bandwidth is itself a repartition
        // trigger: the int8 planner wants away from the fp32 optimum.
        assert!(int8.should_repartition(fp32_plan.split, 5.0).is_some());
        // And the coded optimum beats the raw-fp32 optimum end to end.
        assert!(int8_plan.predicted.total() < fp32_plan.predicted.total());
    }

    #[test]
    fn cpu_avail_shifts_split_towards_cloud() {
        let unstressed = Planner::new(profile(), Duration::from_millis(20));
        let stressed = Planner::new(profile(), Duration::from_millis(20)).with_cpu_avail(0.05);
        assert!(stressed.plan(20.0).split <= unstressed.plan(20.0).split);
    }
}
