//! Frame-flow simulation: what happens to device frames during a downtime
//! window (Figs 14/15).
//!
//! A small discrete-event queueing simulation: frames arrive every `1/fps`,
//! a single server (the still-running old pipeline, or nobody during a
//! baseline pause) serves them with a fixed service time, and a bounded
//! queue absorbs bursts. Frames arriving to a full queue (or while service
//! is stopped and the queue is full) are dropped — the paper's frame drop
//! rate during `t_downtime`.

use std::time::Duration;

/// Outcome of a frame-flow window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowOutcome {
    pub arrivals: u64,
    pub served: u64,
    /// Frames still queued when the window closed (they survive — the new
    /// pipeline will drain them).
    pub queued: u64,
    pub dropped: u64,
}

impl FlowOutcome {
    pub fn drop_rate(&self) -> f64 {
        if self.arrivals == 0 {
            0.0
        } else {
            self.dropped as f64 / self.arrivals as f64
        }
    }
}

/// Simulate a downtime window.
///
/// * `window` — the downtime duration.
/// * `fps` — incoming frame rate.
/// * `service` — per-frame service time of the degraded pipeline, or
///   `None` when service is fully stopped (baseline Pause-and-Resume).
/// * `queue_cap` — bounded frame queue in front of the edge stage.
pub fn simulate_window(
    window: Duration,
    fps: f64,
    service: Option<Duration>,
    queue_cap: usize,
) -> FlowOutcome {
    assert!(fps > 0.0);
    let interval = 1.0 / fps;
    let window_s = window.as_secs_f64();

    let mut out = FlowOutcome { arrivals: 0, served: 0, queued: 0, dropped: 0 };
    // FIFO of arrival times waiting for the (single) server.
    let mut queue: std::collections::VecDeque<f64> = std::collections::VecDeque::new();
    let mut busy_until = 0.0f64; // server free at this instant

    // A frame counts as served when its service *starts* inside the window
    // (it was picked up by the degraded pipeline during the downtime).
    let serve_before = |q: &mut std::collections::VecDeque<f64>,
                            busy_until: &mut f64,
                            horizon: f64,
                            served: &mut u64| {
        if let Some(s) = service {
            let s = s.as_secs_f64();
            while let Some(&arrived) = q.front() {
                let start = busy_until.max(arrived);
                if start < horizon {
                    *busy_until = start + s;
                    q.pop_front();
                    *served += 1;
                } else {
                    break;
                }
            }
        }
    };

    let mut k = 0u64;
    loop {
        let t = k as f64 * interval;
        if t >= window_s {
            break;
        }
        serve_before(&mut queue, &mut busy_until, t, &mut out.served);
        out.arrivals += 1;
        if queue.len() < queue_cap {
            queue.push_back(t);
        } else {
            out.dropped += 1;
        }
        k += 1;
    }
    // Serve whatever can still start before the window closes.
    serve_before(&mut queue, &mut busy_until, window_s, &mut out.served);

    out.queued = queue.len() as u64;
    debug_assert_eq!(out.arrivals, out.served + out.queued + out.dropped);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_drops_overflow_only_queue_absorbs() {
        // Service stopped; queue of 8 absorbs the first 8, rest dropped.
        let o = simulate_window(Duration::from_secs(2), 10.0, None, 8);
        assert_eq!(o.arrivals, 20);
        assert_eq!(o.served, 0);
        assert_eq!(o.queued, 8);
        assert_eq!(o.dropped, 12);
    }

    #[test]
    fn fast_service_drops_nothing() {
        let o = simulate_window(
            Duration::from_secs(2),
            10.0,
            Some(Duration::from_millis(50)),
            8,
        );
        assert_eq!(o.dropped, 0);
        assert!(o.served > 0);
    }

    #[test]
    fn slow_service_drops_some() {
        // 30 fps in, ~3.3 fps service: most frames dropped once queue fills.
        let o = simulate_window(
            Duration::from_secs(3),
            30.0,
            Some(Duration::from_millis(300)),
            4,
        );
        assert!(o.dropped > 0);
        assert!(o.served >= 9); // ~3 s / 0.3 s
        assert!(o.drop_rate() > 0.5);
    }

    #[test]
    fn higher_fps_more_drops() {
        // The trend in Figs 14/15.
        let drop_at = |fps: f64| {
            simulate_window(
                Duration::from_secs(1),
                fps,
                Some(Duration::from_millis(200)),
                4,
            )
            .dropped
        };
        assert!(drop_at(30.0) >= drop_at(15.0));
        assert!(drop_at(15.0) >= drop_at(5.0));
    }

    #[test]
    fn conservation_invariant() {
        for (fps, svc_ms, cap) in [(7.0, 111, 3), (24.0, 45, 10), (60.0, 500, 1)] {
            let o = simulate_window(
                Duration::from_secs(5),
                fps,
                Some(Duration::from_millis(svc_ms)),
                cap,
            );
            assert_eq!(o.arrivals, o.served + o.queued + o.dropped);
        }
    }

    #[test]
    fn zero_window_no_arrivals_edge() {
        let o = simulate_window(Duration::ZERO, 30.0, None, 4);
        assert_eq!(o.arrivals, 0);
        assert_eq!(o.drop_rate(), 0.0);
    }
}
