//! The edge-cloud pipeline: edge partition -> shaped link -> cloud
//! partition, plus its containers and initialisation cost accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::clock::Clock;
use crate::container::{Container, ContainerHost};
use crate::models::ModelManifest;
use crate::netsim::Link;
use crate::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};

use super::state::PipelineState;

static NEXT_PIPELINE_ID: AtomicU64 = AtomicU64::new(1);

/// Where the pipeline's processes live (Case 1 vs Case 2 of §III-B3).
#[derive(Clone)]
pub enum Placement {
    /// Start fresh containers on both hosts (Case 1).
    NewContainers,
    /// Run inside already-running containers (Case 2) — no container
    /// start cost and, per Table I, no additional memory accounted.
    Existing {
        edge: Arc<Container>,
        cloud: Arc<Container>,
    },
}

/// Initialisation cost breakdown (feeds the downtime equations).
#[derive(Debug, Clone, Default)]
pub struct InitStats {
    /// Container start time (zero for Placement::Existing).
    pub container_start: Duration,
    /// Real PJRT compile time for both chains (the "model load").
    pub compile: Duration,
    /// Weight-literal staging time.
    pub weights_upload: Duration,
    /// Simulated application bring-up.
    pub app_bringup: Duration,
    /// Total on the experiment timeline.
    pub total: Duration,
}

/// Per-frame inference result with the Equation-1 breakdown.
pub struct InferenceReport {
    pub t_edge: Duration,
    pub t_transfer: Duration,
    pub t_cloud: Duration,
    pub output: Literal,
}

impl InferenceReport {
    pub fn total(&self) -> Duration {
        self.t_edge + self.t_transfer + self.t_cloud
    }
}

/// A live edge-cloud pipeline executing DNN partitions at one split point.
pub struct Pipeline {
    pub id: u64,
    pub split: usize,
    pub edge_chain: ChainExecutor,
    pub cloud_chain: ChainExecutor,
    pub link: Arc<Link>,
    pub clock: Clock,
    pub edge_container: Arc<Container>,
    pub cloud_container: Arc<Container>,
    pub init_stats: InitStats,
    state: Mutex<PipelineState>,
}

impl Pipeline {
    pub fn state(&self) -> PipelineState {
        *self.state.lock().unwrap()
    }

    /// Validated state transition.
    pub fn transition(&self, to: PipelineState) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        if !s.can_transition(to) {
            bail!("pipeline {}: illegal transition {} -> {}", self.id, *s, to);
        }
        *s = to;
        Ok(())
    }

    /// Process one frame end-to-end: edge partition, uplink transfer of the
    /// intermediate tensor, cloud partition. Fails if the pipeline is not
    /// in a traffic-serving state.
    pub fn infer(&self, frame: &Literal) -> Result<InferenceReport> {
        if !self.state().serves_traffic() {
            bail!("pipeline {} is {}, not serving", self.id, self.state());
        }
        self.infer_unchecked(frame)
    }

    /// Same as [`Self::infer`] without the state gate (warmup, profiling).
    pub fn infer_unchecked(&self, frame: &Literal) -> Result<InferenceReport> {
        let t0 = self.clock.now();
        let (intermediate, edge_t) = self.edge_chain.run(frame, &self.clock)?;
        let t1 = self.clock.now();

        // Ship the split tensor over the shaped uplink. Split 0 ships the
        // raw frame, split N ships the final output back (tiny).
        let bytes = literal_bytes(&intermediate);
        self.link.transfer(bytes);
        let t2 = self.clock.now();

        let (output, cloud_t) = self.cloud_chain.run(&intermediate, &self.clock)?;
        let t3 = self.clock.now();

        // edge/cloud timings come from the chain (dilated); transfer from
        // the link on the timeline. Guard against clock jitter.
        let _ = (t0, t1, t3);
        Ok(InferenceReport {
            t_edge: edge_t.total,
            t_transfer: t2 - t1,
            t_cloud: cloud_t.total,
            output,
        })
    }

    /// Memory currently attributed to this pipeline's containers.
    pub fn memory_mb(&self) -> f64 {
        // Reservations live inside the containers; this is the configured
        // per-pipeline footprint when the pipeline owns its containers.
        0.0 // accounted at the ledger level; see ContainerHost::ledger
    }
}

fn literal_bytes(l: &Literal) -> usize {
    l.size_bytes()
}

/// Factory wiring all substrates together (one per experiment).
pub struct EdgeCloudEnv {
    pub clock: Clock,
    pub cfg: crate::config::ExperimentConfig,
    pub edge: Arc<Domain>,
    pub cloud: Arc<Domain>,
    pub edge_host: Arc<ContainerHost>,
    pub cloud_host: Arc<ContainerHost>,
    pub link: Arc<Link>,
    pub manifest: ModelManifest,
    pub weights: WeightStore,
    /// OS/daemon overhead reservations (held for the env's lifetime).
    _edge_os: crate::container::Reservation,
    _cloud_os: crate::container::Reservation,
}

pub const PIPELINE_IMAGE: &str = "neukonfig/pipeline:optimised";

impl EdgeCloudEnv {
    /// Build an environment from artifacts. `clock` selects realtime vs
    /// simulated sweeps.
    pub fn new(
        cfg: crate::config::ExperimentConfig,
        manifest: ModelManifest,
        clock: Clock,
    ) -> Result<Self> {
        let weights = WeightStore::load(&manifest).context("loading weights")?;
        let edge = Domain::new("edge", cfg.compute.edge_scale)?;
        let cloud = Domain::new("cloud", cfg.compute.cloud_scale)?;
        let link = Arc::new(Link::new(
            clock.clone(),
            cfg.network.high_mbps,
            cfg.network.latency,
        ));
        let edge_host = ContainerHost::new(
            "edge",
            cfg.memory.edge_total_mb,
            cfg.costs.clone(),
            clock.clone(),
        );
        let cloud_host = ContainerHost::new(
            "cloud",
            cfg.memory.cloud_total_mb,
            cfg.costs.clone(),
            clock.clone(),
        );
        // The paper's optimisation: the 575 MB base image is pre-cached on
        // both hosts (§IV-B).
        edge_host.warm_image(PIPELINE_IMAGE);
        cloud_host.warm_image(PIPELINE_IMAGE);
        let _edge_os = edge_host
            .ledger
            .reserve("os-overhead", cfg.memory.os_overhead_mb)?;
        let _cloud_os = cloud_host
            .ledger
            .reserve("os-overhead", cfg.memory.os_overhead_mb)?;
        Ok(EdgeCloudEnv {
            clock,
            cfg,
            edge,
            cloud,
            edge_host,
            cloud_host,
            link,
            manifest,
            weights,
            _edge_os,
            _cloud_os,
        })
    }

    /// Instantiate a pipeline at `split` with the given placement. All real
    /// work (PJRT compile, weight staging) and simulated container costs
    /// land on the experiment clock; the returned [`InitStats`] decomposes
    /// them.
    pub fn build_pipeline(&self, split: usize, placement: Placement) -> Result<Pipeline> {
        self.build_pipeline_opts(split, placement, true)
    }

    /// [`Self::build_pipeline`] with explicit executable-cache control:
    /// Dynamic Switching reuses the per-layer executables already compiled
    /// on each domain (its proactive design); the naive baseline reloads
    /// everything from scratch (`use_cache = false`), like the Keras app
    /// the paper pauses.
    pub fn build_pipeline_opts(
        &self,
        split: usize,
        placement: Placement,
        use_cache: bool,
    ) -> Result<Pipeline> {
        anyhow::ensure!(
            split <= self.manifest.num_layers(),
            "split {split} out of range"
        );
        let t0 = self.clock.now();

        let (edge_c, cloud_c, container_start) = match placement {
            Placement::NewContainers => {
                let tc = self.clock.now();
                let e = self
                    .edge_host
                    .start(PIPELINE_IMAGE, self.cfg.memory.pipeline_mb)
                    .context("starting edge container")?;
                let c = self
                    .cloud_host
                    .start(PIPELINE_IMAGE, self.cfg.memory.pipeline_mb)
                    .context("starting cloud container")?;
                (e, c, self.clock.now() - tc)
            }
            Placement::Existing { edge, cloud } => (edge, cloud, Duration::ZERO),
        };

        // Application bring-up (simulated TF/pyzmq startup inside the
        // container; our PJRT path has no equivalent).
        self.clock.sleep(self.cfg.costs.app_bringup);

        // Real model load: compile the partition executables + stage weights.
        let edge_chain = ChainExecutor::build_opts(
            self.edge.clone(),
            &self.manifest,
            0..split,
            &self.weights,
            use_cache,
        )?;
        let cloud_chain = ChainExecutor::build_opts(
            self.cloud.clone(),
            &self.manifest,
            split..self.manifest.num_layers(),
            &self.weights,
            use_cache,
        )?;

        let compile = edge_chain.build_stats.compile + cloud_chain.build_stats.compile;
        let upload =
            edge_chain.build_stats.weights_upload + cloud_chain.build_stats.weights_upload;

        Ok(Pipeline {
            id: NEXT_PIPELINE_ID.fetch_add(1, Ordering::Relaxed),
            split,
            edge_chain,
            cloud_chain,
            link: self.link.clone(),
            clock: self.clock.clone(),
            edge_container: edge_c,
            cloud_container: cloud_c,
            init_stats: InitStats {
                container_start,
                compile,
                weights_upload: upload,
                app_bringup: self.cfg.costs.app_bringup,
                total: self.clock.now() - t0,
            },
            state: Mutex::new(PipelineState::Initialising),
        })
    }

    /// Frame literal from a device frame.
    pub fn frame_literal(&self, frame: &crate::device::Frame) -> Result<Literal> {
        literal_from_f32(&frame.shape, &frame.pixels)
    }

    /// Proactively compile every partition unit on both domains (fills the
    /// executable caches). Dynamic Switching calls this at deployment so a
    /// later repartition — to *any* split — never pays compilation inside
    /// its downtime window (§III-B "redeployment approaches must be
    /// proactive"). Returns the warming time (deployment cost, not
    /// downtime).
    pub fn warm_executables(&self) -> Result<Duration> {
        let t0 = self.clock.now();
        for domain in [&self.edge, &self.cloud] {
            for i in 0..self.manifest.num_layers() {
                domain.compile_hlo(&self.manifest.hlo_path(i), true)?;
            }
        }
        Ok(self.clock.now() - t0)
    }
}
