//! The edge-cloud pipeline: edge partition -> shaped link -> cloud
//! partition, plus its containers and initialisation cost accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::clock::{Clock, Stopwatch};
use crate::codec::{self, TransferCodec};
use crate::container::{Container, ContainerHost};
use crate::metrics::{CodecStats, FaultStats};
use crate::models::ModelManifest;
use crate::netsim::{FaultPlan, Link, RetryPolicy, TransferAborted};
use crate::runtime::{
    literal_from_f32, BuildOptions, ChainExecutor, Domain, WeightStore,
};
use crate::util::sync::lock_clean;

use super::state::PipelineState;

static NEXT_PIPELINE_ID: AtomicU64 = AtomicU64::new(1);

/// Where the pipeline's processes live (Case 1 vs Case 2 of §III-B3).
#[derive(Clone)]
pub enum Placement {
    /// Start fresh containers on both hosts (Case 1).
    NewContainers,
    /// Run inside already-running containers (Case 2) — no container
    /// start cost and, per Table I, no additional memory accounted.
    Existing {
        edge: Arc<Container>,
        cloud: Arc<Container>,
    },
}

/// Initialisation cost breakdown (feeds the downtime equations).
///
/// Bring-up is parallel (edge and cloud chains build concurrently, each on
/// a worker pool), so the downtime equations consume the *wall-clock*
/// fields while the `_cpu` fields report the cumulative work the pool did
/// — what a serial bring-up would have paid.
#[derive(Debug, Clone, Default)]
pub struct InitStats {
    /// Container start time (zero for Placement::Existing).
    pub container_start: Duration,
    /// Wall-clock compile share of the model load (summed over both
    /// chains' apportioned walls; the chains themselves overlap).
    pub compile: Duration,
    /// Wall-clock weight-staging share of the model load.
    pub weights_upload: Duration,
    /// Cumulative CPU spent compiling across every bring-up worker.
    pub compile_cpu: Duration,
    /// Cumulative CPU spent staging weights across every worker.
    pub weights_upload_cpu: Duration,
    /// Wall-clock of the whole model-load region (both chains, overlapped)
    /// — the term that actually enters the downtime window.
    pub model_load: Duration,
    /// Weight-buffer cache hits/misses over both chains.
    pub weight_cache_hits: u64,
    pub weight_cache_misses: u64,
    /// Simulated application bring-up.
    pub app_bringup: Duration,
    /// Total on the experiment timeline.
    pub total: Duration,
}

/// Per-frame inference result with the Equation-1 breakdown.
pub struct InferenceReport {
    pub t_edge: Duration,
    pub t_transfer: Duration,
    pub t_cloud: Duration,
    /// Per-layer execution times inside the edge chain, in chain order
    /// (dilated like `t_edge`; layer j is manifest layer j). Empty for an
    /// empty chain. Sums to <= `t_edge` — boundary upload/readback is
    /// chain-level, not per-layer.
    pub edge_per_layer: Vec<Duration>,
    /// Per-layer execution times inside the cloud chain, in chain order
    /// (layer j is manifest layer `split + j`).
    pub cloud_per_layer: Vec<Duration>,
    /// Real (wall-clock) time spent encoding the intermediate for the wire
    /// and decoding it cloud-side. Zero for the [`TransferCodec::Fp32`]
    /// identity codec, which never touches the tensor bytes.
    pub t_encode: Duration,
    pub t_decode: Duration,
    /// Raw fp32 bytes of the split tensor vs the bytes actually priced on
    /// the link.
    pub raw_bytes: usize,
    pub wire_bytes: usize,
    pub codec: TransferCodec,
    /// Transfer attempts this frame took (1 on a clean link; more when an
    /// installed fault plan forced retries; 0 for edge-only frames, which
    /// never touch the link).
    pub transfer_attempts: u32,
    /// Time slept between transfer attempts (zero without faults).
    pub t_backoff: Duration,
    pub output: Literal,
}

impl InferenceReport {
    pub fn total(&self) -> Duration {
        self.t_edge + self.t_encode + self.t_transfer + self.t_backoff + self.t_decode
            + self.t_cloud
    }

    /// Raw-to-wire size ratio for this frame (1.0 for empty payloads).
    pub fn compression_ratio(&self) -> f64 {
        if self.wire_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.wire_bytes as f64
        }
    }
}

/// What one uplink hand-off cost: codec timings plus the link charge.
#[derive(Debug, Clone, Copy)]
pub struct TransferReport {
    pub codec: TransferCodec,
    /// Link time across every attempt (failed attempts' burnt time
    /// included — the link really was occupied).
    pub t_transfer: Duration,
    pub t_encode: Duration,
    pub t_decode: Duration,
    pub raw_bytes: usize,
    pub wire_bytes: usize,
    /// Attempts made (1 on a clean link).
    pub attempts: u32,
    /// Backoff slept between attempts.
    pub t_backoff: Duration,
}

/// A live edge-cloud pipeline executing DNN partitions at one split point.
pub struct Pipeline {
    pub id: u64,
    pub split: usize,
    pub edge_chain: ChainExecutor,
    pub cloud_chain: ChainExecutor,
    pub link: Arc<Link>,
    pub clock: Clock,
    pub edge_container: Arc<Container>,
    pub cloud_container: Arc<Container>,
    pub init_stats: InitStats,
    /// How the intermediate tensor is packed for the uplink.
    pub codec: TransferCodec,
    /// Chunk size for [`Link::transfer_chunked`] — bounds how stale a
    /// bandwidth change can go before the remaining payload is repriced.
    pub chunk_bytes: usize,
    /// Cumulative codec counters over this pipeline's frames.
    pub codec_stats: CodecStats,
    /// Retry discipline for faultable transfers (inert on clean links).
    pub retry: RetryPolicy,
    /// Retry/backoff/drop counters over this pipeline's frames.
    pub fault_stats: FaultStats,
    state: Mutex<PipelineState>,
}

impl Pipeline {
    pub fn state(&self) -> PipelineState {
        *lock_clean(&self.state)
    }

    /// Validated state transition.
    pub fn transition(&self, to: PipelineState) -> Result<()> {
        let mut s = lock_clean(&self.state);
        if !s.can_transition(to) {
            bail!("pipeline {}: illegal transition {} -> {}", self.id, *s, to);
        }
        *s = to;
        Ok(())
    }

    /// Process one frame end-to-end: edge partition, uplink transfer of the
    /// intermediate tensor, cloud partition. Fails if the pipeline is not
    /// in a traffic-serving state.
    pub fn infer(&self, frame: &Literal) -> Result<InferenceReport> {
        if !self.state().serves_traffic() {
            bail!("pipeline {} is {}, not serving", self.id, self.state());
        }
        self.infer_unchecked(frame)
    }

    /// Same as [`Self::infer`] without the state gate (warmup, profiling).
    ///
    /// Every component of the report comes from its own authority, not
    /// from clock deltas: the chains report their dilated execution times
    /// and [`Link::transfer`] returns the queueing + serialisation time it
    /// charged. The experiment clock is shared — control-plane work on
    /// another thread (a concurrent standby rebuild, a `PipelinedRunner`
    /// stage) advances it mid-frame, so `now()` deltas here would blame
    /// that foreign time on this frame.
    pub fn infer_unchecked(&self, frame: &Literal) -> Result<InferenceReport> {
        let (intermediate, edge_t) = self.edge_chain.run(frame, &self.clock)?;

        // Ship the split tensor over the shaped uplink. Split 0 ships the
        // raw frame, split N ships the final output back (tiny).
        let (cloud_input, xfer) = self.ship(intermediate)?;

        let (output, cloud_t) = self.cloud_chain.run(&cloud_input, &self.clock)?;

        Ok(InferenceReport {
            t_edge: edge_t.total,
            t_transfer: xfer.t_transfer,
            t_cloud: cloud_t.total,
            edge_per_layer: edge_t.per_layer,
            cloud_per_layer: cloud_t.per_layer,
            t_encode: xfer.t_encode,
            t_decode: xfer.t_decode,
            raw_bytes: xfer.raw_bytes,
            wire_bytes: xfer.wire_bytes,
            codec: xfer.codec,
            transfer_attempts: xfer.attempts,
            t_backoff: xfer.t_backoff,
            output,
        })
    }

    /// Degraded-mode inference (§III-B "degraded until switch"): run only
    /// the edge chain, never touching the link or the cloud chain. Valid
    /// only for a full-model split (empty cloud chain) — the fallback
    /// pipeline the router arms via `Router::arm_degraded`. No state gate:
    /// the fallback serves from `Standby` while the real pipeline is
    /// nominally `Active`; the router is the authority on when degraded
    /// frames are allowed.
    pub fn infer_edge_only(&self, frame: &Literal) -> Result<InferenceReport> {
        anyhow::ensure!(
            self.cloud_chain.is_empty(),
            "pipeline {}: edge-only inference needs the full model on the edge \
             (split {}, cloud chain non-empty)",
            self.id,
            self.split,
        );
        let (output, edge_t) = self.edge_chain.run(frame, &self.clock)?;
        Ok(InferenceReport {
            t_edge: edge_t.total,
            t_transfer: Duration::ZERO,
            t_cloud: Duration::ZERO,
            edge_per_layer: edge_t.per_layer,
            cloud_per_layer: Vec::new(),
            t_encode: Duration::ZERO,
            t_decode: Duration::ZERO,
            raw_bytes: 0,
            wire_bytes: 0,
            codec: self.codec,
            transfer_attempts: 0,
            t_backoff: Duration::ZERO,
            output,
        })
    }

    /// Charge the link for `wire_bytes` under this pipeline's
    /// [`RetryPolicy`]: retry faulted attempts with exponential backoff
    /// until success, attempt exhaustion, or the deadline passes. Returns
    /// `(link_time_across_attempts, backoff_slept, attempts)`. On a link
    /// with no fault plan this is a single infallible transfer with the
    /// historical cost arithmetic — no retry bookkeeping at all.
    fn transfer_with_retry(&self, wire_bytes: usize) -> Result<(Duration, Duration, u32)> {
        if !self.link.has_fault_plan() {
            let t = self.link.transfer_chunked(wire_bytes, self.chunk_bytes);
            return Ok((t, Duration::ZERO, 1));
        }
        let policy = self.retry;
        let t0 = self.clock.now();
        let mut link_time = Duration::ZERO;
        let mut backoff_total = Duration::ZERO;
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            if attempt > 1 {
                let pause = policy.backoff_before(attempt);
                self.clock.sleep(pause);
                backoff_total += pause;
                self.fault_stats.record_retry(pause);
            }
            match self.link.try_transfer_chunked(wire_bytes, self.chunk_bytes) {
                Ok(t) => return Ok((link_time + t, backoff_total, attempt)),
                Err(f) => {
                    link_time += f.elapsed;
                    let deadline_exceeded = policy
                        .deadline
                        .is_some_and(|dl| self.clock.now() - t0 >= dl);
                    if attempt >= policy.max_attempts || deadline_exceeded {
                        self.fault_stats.record_dropped_frame();
                        return Err(anyhow::Error::new(TransferAborted {
                            attempts: attempt,
                            last_fault: f.kind,
                            deadline_exceeded,
                            elapsed: link_time,
                        })
                        .context(format!("transfer of {wire_bytes} bytes abandoned")));
                    }
                }
            }
        }
    }

    /// Encode the split tensor with this pipeline's codec, charge the link
    /// for the *wire* bytes (chunked, so scheduled bandwidth changes
    /// reprice the remaining payload), and decode cloud-side. Returns the
    /// literal the cloud chain must consume — for [`TransferCodec::Fp32`]
    /// it is the untouched input (bitwise-identical fast path); for lossy
    /// codecs it carries the quantisation round-trip.
    pub fn ship(&self, intermediate: Literal) -> Result<(Literal, TransferReport)> {
        let raw_bytes = literal_bytes(&intermediate);
        if self.codec == TransferCodec::Fp32 {
            let (t_transfer, t_backoff, attempts) = self.transfer_with_retry(raw_bytes)?;
            let rep = TransferReport {
                codec: self.codec,
                t_transfer,
                t_encode: Duration::ZERO,
                t_decode: Duration::ZERO,
                raw_bytes,
                wire_bytes: raw_bytes,
                attempts,
                t_backoff,
            };
            self.codec_stats
                .record(rep.raw_bytes, rep.wire_bytes, rep.t_encode, rep.t_decode);
            return Ok((intermediate, rep));
        }
        let t0 = Stopwatch::start();
        let enc = codec::encode_literal(self.codec, &intermediate)?;
        let t_encode = t0.elapsed();
        let wire_bytes = enc.wire_bytes();
        let (t_transfer, t_backoff, attempts) = self.transfer_with_retry(wire_bytes)?;
        let t1 = Stopwatch::start();
        let decoded = codec::decode_literal(&enc)?;
        let t_decode = t1.elapsed();
        let rep = TransferReport {
            codec: self.codec,
            t_transfer,
            t_encode,
            t_decode,
            raw_bytes,
            wire_bytes,
            attempts,
            t_backoff,
        };
        self.codec_stats.record(raw_bytes, wire_bytes, t_encode, t_decode);
        Ok((decoded, rep))
    }

    /// Wire a pipeline directly from parts, with zeroed init stats, in the
    /// `Initialising` state (callers `transition` it onward). This skips
    /// `EdgeCloudEnv::build_pipeline`'s cost accounting and its boundary
    /// validation — fault-injection tests use it to assemble deliberately
    /// mismatched chains and watch the runner fail cleanly.
    pub fn assemble(
        split: usize,
        edge_chain: ChainExecutor,
        cloud_chain: ChainExecutor,
        link: Arc<Link>,
        clock: Clock,
        edge_container: Arc<Container>,
        cloud_container: Arc<Container>,
    ) -> Pipeline {
        Pipeline {
            id: NEXT_PIPELINE_ID.fetch_add(1, Ordering::Relaxed),
            split,
            edge_chain,
            cloud_chain,
            link,
            clock,
            edge_container,
            cloud_container,
            init_stats: InitStats::default(),
            codec: TransferCodec::from_env(),
            chunk_bytes: crate::netsim::default_chunk_bytes(),
            codec_stats: CodecStats::default(),
            retry: RetryPolicy::default(),
            fault_stats: FaultStats::default(),
            state: Mutex::new(PipelineState::Initialising),
        }
    }

    /// Memory currently attributed to this pipeline's containers on the
    /// hosts' ledgers. With [`Placement::Existing`] the containers are
    /// shared with the pipeline they were borrowed from, so (per Table I)
    /// the footprint is attributed to both pipelines, not doubled on the
    /// ledger itself.
    pub fn memory_mb(&self) -> f64 {
        self.edge_container.memory_mb() + self.cloud_container.memory_mb()
    }
}

fn literal_bytes(l: &Literal) -> usize {
    l.size_bytes()
}

/// Factory wiring all substrates together (one per experiment).
pub struct EdgeCloudEnv {
    pub clock: Clock,
    pub cfg: crate::config::ExperimentConfig,
    pub edge: Arc<Domain>,
    pub cloud: Arc<Domain>,
    pub edge_host: Arc<ContainerHost>,
    pub cloud_host: Arc<ContainerHost>,
    pub link: Arc<Link>,
    pub manifest: ModelManifest,
    pub weights: WeightStore,
    /// OS/daemon overhead reservations (held for the env's lifetime).
    _edge_os: crate::container::Reservation,
    _cloud_os: crate::container::Reservation,
}

pub const PIPELINE_IMAGE: &str = "neukonfig/pipeline:optimised";

impl EdgeCloudEnv {
    /// Build an environment from artifacts. `clock` selects realtime vs
    /// simulated sweeps.
    pub fn new(
        cfg: crate::config::ExperimentConfig,
        manifest: ModelManifest,
        clock: Clock,
    ) -> Result<Self> {
        let weights = WeightStore::load(&manifest).context("loading weights")?;
        let edge = Domain::new("edge", cfg.compute.edge_scale)?;
        let cloud = Domain::new("cloud", cfg.compute.cloud_scale)?;
        let link = Arc::new(Link::new(
            clock.clone(),
            cfg.network.high_mbps,
            cfg.network.latency,
        ));
        // Opt-in fault injection: NEUKONFIG_FAULT_PROFILE attaches a
        // seeded fault schedule to the uplink (no profile, no plan — and
        // the link stays bit-identical to the clean model).
        if let Some(plan) = FaultPlan::from_env() {
            link.install_fault_plan(plan);
        }
        let edge_host = ContainerHost::new(
            "edge",
            cfg.memory.edge_total_mb,
            cfg.costs.clone(),
            clock.clone(),
        );
        let cloud_host = ContainerHost::new(
            "cloud",
            cfg.memory.cloud_total_mb,
            cfg.costs.clone(),
            clock.clone(),
        );
        // The paper's optimisation: the 575 MB base image is pre-cached on
        // both hosts (§IV-B).
        edge_host.warm_image(PIPELINE_IMAGE);
        cloud_host.warm_image(PIPELINE_IMAGE);
        let _edge_os = edge_host
            .ledger
            .reserve("os-overhead", cfg.memory.os_overhead_mb)?;
        let _cloud_os = cloud_host
            .ledger
            .reserve("os-overhead", cfg.memory.os_overhead_mb)?;
        Ok(EdgeCloudEnv {
            clock,
            cfg,
            edge,
            cloud,
            edge_host,
            cloud_host,
            link,
            manifest,
            weights,
            _edge_os,
            _cloud_os,
        })
    }

    /// Instantiate a pipeline at `split` with the given placement. All real
    /// work (PJRT compile, weight staging) and simulated container costs
    /// land on the experiment clock; the returned [`InitStats`] decomposes
    /// them.
    pub fn build_pipeline(&self, split: usize, placement: Placement) -> Result<Pipeline> {
        self.build_pipeline_opts(split, placement, true)
    }

    /// [`Self::build_pipeline`] with explicit executable-cache control:
    /// Dynamic Switching reuses the per-layer executables already compiled
    /// on each domain (its proactive design); the naive baseline reloads
    /// everything from scratch (`use_cache = false`), like the Keras app
    /// the paper pauses.
    pub fn build_pipeline_opts(
        &self,
        split: usize,
        placement: Placement,
        use_cache: bool,
    ) -> Result<Pipeline> {
        self.build_pipeline_with(
            split,
            placement,
            BuildOptions { use_cache, ..Default::default() },
        )
    }

    /// [`Self::build_pipeline`] with full [`BuildOptions`] control — the
    /// transfer codec chosen there follows the pipeline for life.
    pub fn build_pipeline_with(
        &self,
        split: usize,
        placement: Placement,
        opts: BuildOptions,
    ) -> Result<Pipeline> {
        anyhow::ensure!(
            split <= self.manifest.num_layers(),
            "split {split} out of range"
        );
        let t0 = self.clock.now();

        let (edge_c, cloud_c, container_start) = match placement {
            Placement::NewContainers => {
                let tc = self.clock.now();
                let e = self
                    .edge_host
                    .start(PIPELINE_IMAGE, self.cfg.memory.pipeline_mb)
                    .context("starting edge container")?;
                let c = self
                    .cloud_host
                    .start(PIPELINE_IMAGE, self.cfg.memory.pipeline_mb)
                    .context("starting cloud container")?;
                (e, c, self.clock.now() - tc)
            }
            Placement::Existing { edge, cloud } => (edge, cloud, Duration::ZERO),
        };

        // Application bring-up (simulated TF/pyzmq startup inside the
        // container; our PJRT path has no equivalent).
        self.clock.sleep(self.cfg.costs.app_bringup);

        // Real model load: compile the partition executables + stage
        // weights. The two chains live on different domains (different
        // PJRT clients), so they build concurrently — the edge and cloud
        // servers initialise in parallel in the paper's testbed too.
        let n = self.manifest.num_layers();
        let t_load = self.clock.now();
        let (edge_chain, cloud_chain) = if opts.parallel {
            let mut cloud_res: Option<Result<ChainExecutor>> = None;
            let edge_res = std::thread::scope(|s| {
                let cloud_handle = s.spawn(|| {
                    ChainExecutor::build_with(
                        self.cloud.clone(),
                        &self.manifest,
                        split..n,
                        &self.weights,
                        opts,
                    )
                });
                let edge = ChainExecutor::build_with(
                    self.edge.clone(),
                    &self.manifest,
                    0..split,
                    &self.weights,
                    opts,
                );
                cloud_res = Some(
                    cloud_handle
                        .join()
                        .unwrap_or_else(|_| Err(anyhow::anyhow!("cloud bring-up panicked"))),
                );
                edge
            });
            (edge_res?, cloud_res.expect("cloud chain built")?)
        } else {
            (
                ChainExecutor::build_with(
                    self.edge.clone(),
                    &self.manifest,
                    0..split,
                    &self.weights,
                    opts,
                )?,
                ChainExecutor::build_with(
                    self.cloud.clone(),
                    &self.manifest,
                    split..n,
                    &self.weights,
                    opts,
                )?,
            )
        };
        let model_load = self.clock.now() - t_load;

        let es = &edge_chain.build_stats;
        let cs = &cloud_chain.build_stats;

        Ok(Pipeline {
            id: NEXT_PIPELINE_ID.fetch_add(1, Ordering::Relaxed),
            split,
            link: self.link.clone(),
            clock: self.clock.clone(),
            edge_container: edge_c,
            cloud_container: cloud_c,
            init_stats: InitStats {
                container_start,
                compile: es.compile + cs.compile,
                weights_upload: es.weights_upload + cs.weights_upload,
                compile_cpu: es.compile_cpu + cs.compile_cpu,
                weights_upload_cpu: es.weights_upload_cpu + cs.weights_upload_cpu,
                model_load,
                weight_cache_hits: es.weight_cache_hits + cs.weight_cache_hits,
                weight_cache_misses: es.weight_cache_misses + cs.weight_cache_misses,
                app_bringup: self.cfg.costs.app_bringup,
                total: self.clock.now() - t0,
            },
            edge_chain,
            cloud_chain,
            codec: opts.transfer_codec,
            chunk_bytes: crate::netsim::default_chunk_bytes(),
            codec_stats: CodecStats::default(),
            retry: self.cfg.retry,
            fault_stats: FaultStats::default(),
            state: Mutex::new(PipelineState::Initialising),
        })
    }

    /// Frame literal from a device frame.
    pub fn frame_literal(&self, frame: &crate::device::Frame) -> Result<Literal> {
        literal_from_f32(&frame.shape, &frame.pixels)
    }

    /// Proactively compile every partition unit AND stage its weight
    /// buffers on both domains (fills the executable and weight caches).
    /// Dynamic Switching calls this at deployment so a later repartition —
    /// to *any* split — never pays compilation or weight upload inside its
    /// downtime window (§III-B "redeployment approaches must be
    /// proactive"). The (domain x layer) jobs run on a scoped worker pool;
    /// returns the warming wall time (deployment cost, not downtime).
    pub fn warm_executables(&self) -> Result<Duration> {
        let t0 = self.clock.now();
        let n = self.manifest.num_layers();
        let domains = [&self.edge, &self.cloud];
        let jobs: Vec<(usize, usize)> = (0..domains.len())
            .flat_map(|d| (0..n).map(move |i| (d, i)))
            .collect();
        let cursor = std::sync::atomic::AtomicUsize::new(0);
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let workers = if crate::runtime::default_parallel_bringup() {
            std::thread::available_parallelism()
                .map(|w| w.get())
                .unwrap_or(4)
                .min(jobs.len())
                .max(1)
        } else {
            1
        };
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let k = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if k >= jobs.len() || lock_clean(&failure).is_some() {
                        break;
                    }
                    let (d, i) = jobs[k];
                    let domain = domains[d];
                    let warm_one = || -> Result<()> {
                        domain.compile_hlo(&self.manifest.hlo_path(i), true)?;
                        domain.layer_weight_buffers(
                            &self.weights,
                            &self.manifest.layers[i],
                            true,
                        )?;
                        Ok(())
                    };
                    if let Err(e) = warm_one() {
                        lock_clean(&failure).get_or_insert(e);
                        break;
                    }
                });
            }
        });
        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        Ok(self.clock.now() - t0)
    }
}
