//! Dynamic Switching (§III-B) — the paper's contribution.
//!
//! Instead of freezing the running pipeline, a second edge-cloud pipeline
//! with the new partitions is made available and incoming requests are
//! atomically redirected to it. The original pipeline keeps serving
//! (degraded) until the switch, so "downtime" is a quality-degradation
//! window, not a blackout.
//!
//! * **Scenario A** — a redundant pipeline is always running; downtime is
//!   just the router switch (Equation 3, sub-millisecond).
//! * **Scenario B Case 1** — new containers are started on both hosts when
//!   the speed changes; downtime = container init + model load + switch
//!   (Equation 4).
//! * **Scenario B Case 2** — the new pipeline is launched inside the
//!   existing containers; downtime = model load + switch (Equation 5).
//!
//! Case 1 doubles the memory footprint (permanently for A, transiently for
//! B); Case 2 stays within the baseline footprint (Table I).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};
use xla::Literal;

use crate::metrics::DowntimeRecord;
use crate::util::sync::lock_clean;

use super::pipeline::{EdgeCloudEnv, Pipeline, Placement};
use super::router::Router;
use super::state::PipelineState;

/// Case 1 (new container) vs Case 2 (existing container) of §III-B3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementCase {
    NewContainer,
    SameContainer,
}

/// Scenario A: hot-standby redundant pipeline.
pub struct ScenarioA {
    pub env: Arc<EdgeCloudEnv>,
    pub router: Arc<Router>,
    pub case: PlacementCase,
    standby: Mutex<Option<Arc<Pipeline>>>,
}

impl ScenarioA {
    /// Deploy the active pipeline at `active_split` and a warm standby at
    /// `standby_split` (the optimum for the *other* network condition).
    pub fn deploy(
        env: Arc<EdgeCloudEnv>,
        active_split: usize,
        standby_split: usize,
        case: PlacementCase,
    ) -> Result<Self> {
        let active = Arc::new(env.build_pipeline(active_split, Placement::NewContainers)?);
        let router = Arc::new(Router::new(env.clock.clone(), active.clone())?);
        let placement = match case {
            PlacementCase::NewContainer => Placement::NewContainers,
            PlacementCase::SameContainer => Placement::Existing {
                edge: active.edge_container.clone(),
                cloud: active.cloud_container.clone(),
            },
        };
        let standby = Arc::new(env.build_pipeline(standby_split, placement)?);
        standby.transition(PipelineState::Standby)?;
        // Proactive: precompile every unit AND stage its weight buffers on
        // both domains so later ensure_standby() rebuilds pay neither
        // compilation nor weight upload (weights_upload ~ 0).
        env.warm_executables()?;
        Ok(ScenarioA { env, router, case, standby: Mutex::new(Some(standby)) })
    }

    pub fn standby_split(&self) -> Option<usize> {
        lock_clean(&self.standby).as_ref().map(|p| p.split)
    }

    /// Switch traffic to the standby pipeline. Downtime = t_switch
    /// (Equation 3). The displaced pipeline becomes the new standby (it
    /// already holds the right partitions for the reverse toggle).
    pub fn switch(&self) -> Result<DowntimeRecord> {
        let clock = &self.env.clock;
        let sim0 = clock.simulated_component();
        let t0 = clock.now();
        let mut rec = DowntimeRecord::default();

        self.router.set_downtime(true);
        let standby = lock_clean(&self.standby)
            .take()
            .context("no standby pipeline available")?;
        let (old, t_switch) = self.router.switch(standby)?;
        rec.push_phase("switch", t_switch);
        self.router.set_downtime(false);

        rec.total = clock.now() - t0;
        rec.simulated = clock.simulated_component() - sim0;

        // Outside the downtime window: recycle the displaced pipeline as
        // the new standby.
        old.transition(PipelineState::Standby)?;
        *lock_clean(&self.standby) = Some(old);
        Ok(rec)
    }

    /// [`Self::switch`] with a probe-first rollback guard: the standby is
    /// probed *before* the router swap. If the probe fails (a faulted
    /// link exhausting retries, a broken chain), the router stays on the
    /// old pipeline, the standby is put back untouched, and the returned
    /// record is marked `aborted` with an `aborted-switch` phase — the
    /// window cost time but changed nothing.
    pub fn switch_probed(&self, probe: &Literal) -> Result<DowntimeRecord> {
        let clock = &self.env.clock;
        let sim0 = clock.simulated_component();
        let t0 = clock.now();
        let mut rec = DowntimeRecord::default();

        self.router.set_downtime(true);
        let standby = lock_clean(&self.standby)
            .take()
            .context("no standby pipeline available")?;
        match self.router.switch_probed(standby.clone(), probe) {
            Ok((old, t_switch)) => {
                rec.push_phase("switch", t_switch);
                self.router.set_downtime(false);
                rec.total = clock.now() - t0;
                rec.simulated = clock.simulated_component() - sim0;
                old.transition(PipelineState::Standby)?;
                *lock_clean(&self.standby) = Some(old);
            }
            Err(_) => {
                // Rollback: the router never swapped (switch_probed counted
                // the abort); the standby is still Standby — restore it.
                self.router.set_downtime(false);
                rec.aborted = true;
                rec.push_phase("aborted-switch", clock.now() - t0);
                rec.total = clock.now() - t0;
                rec.simulated = clock.simulated_component() - sim0;
                *lock_clean(&self.standby) = Some(standby);
            }
        }
        Ok(rec)
    }

    /// Rebuild the standby at a different split (background work after a
    /// plan change; NOT part of any downtime window). Returns the rebuild
    /// duration.
    pub fn ensure_standby(&self, split: usize) -> Result<Duration> {
        let current = self.standby_split();
        if current == Some(split) {
            return Ok(Duration::ZERO);
        }
        let clock = &self.env.clock;
        let t0 = clock.now();
        let old = lock_clean(&self.standby).take();
        if let Some(p) = old {
            p.transition(PipelineState::Terminated)?;
            if self.case == PlacementCase::NewContainer {
                self.env.edge_host.stop(&p.edge_container);
                self.env.cloud_host.stop(&p.cloud_container);
            }
        }
        let active = self.router.active();
        let placement = match self.case {
            PlacementCase::NewContainer => Placement::NewContainers,
            PlacementCase::SameContainer => Placement::Existing {
                edge: active.edge_container.clone(),
                cloud: active.cloud_container.clone(),
            },
        };
        let standby = Arc::new(self.env.build_pipeline(split, placement)?);
        standby.transition(PipelineState::Standby)?;
        *lock_clean(&self.standby) = Some(standby);
        Ok(clock.now() - t0)
    }
}

/// Scenario B: the second pipeline is created only when the speed changes.
pub struct ScenarioB {
    pub env: Arc<EdgeCloudEnv>,
    pub router: Arc<Router>,
    pub case: PlacementCase,
}

impl ScenarioB {
    pub fn deploy(env: Arc<EdgeCloudEnv>, initial_split: usize) -> Result<ScenarioBBuilder> {
        let active = Arc::new(env.build_pipeline(initial_split, Placement::NewContainers)?);
        let router = Arc::new(Router::new(env.clock.clone(), active)?);
        // Proactive (§III-B): precompile every unit and stage its weight
        // buffers on both domains at deployment so the repartition window
        // pays neither compilation nor weight upload.
        env.warm_executables()?;
        Ok(ScenarioBBuilder { env, router })
    }

    /// Repartition to `new_split`: initialise the second pipeline (per the
    /// case), then switch. Downtime = t_init + t_switch (Eq 4) or
    /// t_exec + t_switch (Eq 5). The old pipeline serves throughout.
    pub fn repartition(&self, new_split: usize) -> Result<DowntimeRecord> {
        let clock = &self.env.clock;
        let sim0 = clock.simulated_component();
        let t0 = clock.now();
        let mut rec = DowntimeRecord::default();

        self.router.set_downtime(true);
        let old_active = self.router.active();

        let placement = match self.case {
            PlacementCase::NewContainer => Placement::NewContainers,
            PlacementCase::SameContainer => Placement::Existing {
                edge: old_active.edge_container.clone(),
                cloud: old_active.cloud_container.clone(),
            },
        };
        let new_pipe = Arc::new(self.env.build_pipeline(new_split, placement)?);
        let t_init = clock.now() - t0;
        rec.push_phase(
            match self.case {
                PlacementCase::NewContainer => "initialisation",
                PlacementCase::SameContainer => "exec",
            },
            t_init,
        );

        let (old, t_switch) = self.router.switch(new_pipe)?;
        rec.push_phase("switch", t_switch);
        self.router.set_downtime(false);

        rec.total = clock.now() - t0;
        rec.simulated = clock.simulated_component() - sim0;

        // Retire the displaced pipeline (outside the downtime window);
        // Case 1 releases its containers, ending the transient 2x memory.
        old.transition(PipelineState::Terminated)?;
        if self.case == PlacementCase::NewContainer && !Arc::ptr_eq(&old, &self.router.active()) {
            self.env.edge_host.stop(&old.edge_container);
            self.env.cloud_host.stop(&old.cloud_container);
        }
        Ok(rec)
    }

    /// [`Self::repartition`] with rollback on *both* failure points: a
    /// failed bring-up (the new pipeline never came up) and a failed
    /// pre-swap probe both leave the router serving the old pipeline and
    /// return an `aborted` record instead of an error — the repartition
    /// simply did not happen, which for a trigger loop is a condition to
    /// note, not a crash. Contrast [`Self::repartition`], which
    /// propagates bring-up errors (the memory-exhaustion experiments
    /// depend on seeing them).
    pub fn repartition_guarded(
        &self,
        new_split: usize,
        probe: &Literal,
    ) -> Result<DowntimeRecord> {
        let clock = &self.env.clock;
        let sim0 = clock.simulated_component();
        let t0 = clock.now();
        let mut rec = DowntimeRecord::default();

        self.router.set_downtime(true);
        let old_active = self.router.active();
        let placement = match self.case {
            PlacementCase::NewContainer => Placement::NewContainers,
            PlacementCase::SameContainer => Placement::Existing {
                edge: old_active.edge_container.clone(),
                cloud: old_active.cloud_container.clone(),
            },
        };
        let new_pipe = match self.env.build_pipeline(new_split, placement) {
            Ok(p) => Arc::new(p),
            Err(_) => {
                // Stillborn bring-up: nothing to retire, nothing swapped.
                self.router.set_downtime(false);
                self.router.fault_stats.record_aborted_switch();
                rec.aborted = true;
                rec.push_phase("aborted-bringup", clock.now() - t0);
                rec.total = clock.now() - t0;
                rec.simulated = clock.simulated_component() - sim0;
                return Ok(rec);
            }
        };
        let t_init = clock.now() - t0;
        rec.push_phase(
            match self.case {
                PlacementCase::NewContainer => "initialisation",
                PlacementCase::SameContainer => "exec",
            },
            t_init,
        );

        let t_probe = clock.now();
        match self.router.switch_probed(new_pipe.clone(), probe) {
            Ok((old, t_switch)) => {
                rec.push_phase("switch", t_switch);
                self.router.set_downtime(false);
                rec.total = clock.now() - t0;
                rec.simulated = clock.simulated_component() - sim0;
                old.transition(PipelineState::Terminated)?;
                if self.case == PlacementCase::NewContainer
                    && !Arc::ptr_eq(&old, &self.router.active())
                {
                    self.env.edge_host.stop(&old.edge_container);
                    self.env.cloud_host.stop(&old.cloud_container);
                }
            }
            Err(_) => {
                // Probe failed: the router never swapped (switch_probed
                // counted the abort). Retire the stillborn pipeline; Case 1
                // releases its containers, ending the transient 2x memory.
                self.router.set_downtime(false);
                rec.aborted = true;
                rec.push_phase("aborted-switch", clock.now() - t_probe);
                rec.total = clock.now() - t0;
                rec.simulated = clock.simulated_component() - sim0;
                new_pipe.transition(PipelineState::Terminated)?;
                if self.case == PlacementCase::NewContainer {
                    self.env.edge_host.stop(&new_pipe.edge_container);
                    self.env.cloud_host.stop(&new_pipe.cloud_container);
                }
            }
        }
        Ok(rec)
    }

    /// [`Self::repartition`], then run one probe frame on the new active
    /// pipeline and append its per-layer timings to the record as
    /// `edge/layerN` / `cloud/layerN` phases. The probe runs *after* the
    /// switch (outside the downtime window — `total` is unchanged), so the
    /// record answers both "how long was the switch" and "where does
    /// steady-state time go at the new split" in one artifact, feeding
    /// [`ModelProfile::apply_observation`].
    ///
    /// [`ModelProfile::apply_observation`]:
    /// crate::profiler::ModelProfile::apply_observation
    pub fn repartition_probed(
        &self,
        new_split: usize,
        probe: &Literal,
    ) -> Result<DowntimeRecord> {
        let mut rec = self.repartition(new_split)?;
        let active = self.router.active();
        let report = active.infer(probe).context("probe frame after switch")?;
        rec.push_layer_phases("edge", 0, &report.edge_per_layer);
        rec.push_layer_phases("cloud", active.split, &report.cloud_per_layer);
        Ok(rec)
    }
}

/// Build and arm the degraded fallback: the full model on the edge
/// (split = N, empty cloud chain) inside the active pipeline's existing
/// containers — no extra container start and (per Table I's Case-2
/// accounting) no additional memory. Once armed, retry exhaustion on the
/// uplink flips the router into edge-only serving (§III-B "degraded until
/// switch") until the next successful switch closes the window.
pub fn arm_degraded_fallback(env: &EdgeCloudEnv, router: &Router) -> Result<Arc<Pipeline>> {
    let active = router.active();
    let full = env.manifest.num_layers();
    let fallback = Arc::new(env.build_pipeline(
        full,
        Placement::Existing {
            edge: active.edge_container.clone(),
            cloud: active.cloud_container.clone(),
        },
    )?);
    router.arm_degraded(fallback.clone())?;
    Ok(fallback)
}

impl ScenarioA {
    /// [`arm_degraded_fallback`] for this scenario's env and router.
    pub fn arm_degraded_fallback(&self) -> Result<Arc<Pipeline>> {
        arm_degraded_fallback(&self.env, &self.router)
    }
}

impl ScenarioB {
    /// [`arm_degraded_fallback`] for this scenario's env and router.
    pub fn arm_degraded_fallback(&self) -> Result<Arc<Pipeline>> {
        arm_degraded_fallback(&self.env, &self.router)
    }
}

/// Intermediate so callers pick the case after deploy (both cases share
/// the deployed initial pipeline).
pub struct ScenarioBBuilder {
    pub env: Arc<EdgeCloudEnv>,
    pub router: Arc<Router>,
}

impl ScenarioBBuilder {
    pub fn with_case(self, case: PlacementCase) -> ScenarioB {
        ScenarioB { env: self.env, router: self.router, case }
    }
}
