//! Request router: directs device frames to the active pipeline and
//! implements the atomic switch at the heart of Dynamic Switching.
//!
//! The switch is an `Arc` pointer swap under an `RwLock` — the measured
//! `t_switch` of Equation 3. During a baseline pause the router drops
//! every frame (the paper: "no frames sent from the device to the edge
//! will be processed"); during a Dynamic Switching window frames keep
//! flowing to the old pipeline at degraded quality.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use anyhow::Result;
use xla::Literal;

use crate::clock::Clock;
use crate::metrics::{FrameStats, LatencyHistogram};

use super::pipeline::{InferenceReport, Pipeline};
use super::runner::PipelinedRunner;
use super::state::PipelineState;

/// Outcome of routing one frame.
pub enum RouteOutcome {
    Processed(InferenceReport),
    /// Dropped because the router is paused (baseline downtime).
    DroppedPaused,
}

pub struct Router {
    active: RwLock<Arc<Pipeline>>,
    paused: AtomicBool,
    /// Set while a repartition window is open (frame-drop attribution).
    in_downtime: AtomicBool,
    pub clock: Clock,
    pub stats: FrameStats,
    pub latency: LatencyHistogram,
}

impl Router {
    /// Create a router over an initial pipeline, activating it.
    pub fn new(clock: Clock, initial: Arc<Pipeline>) -> Result<Self> {
        initial.transition(PipelineState::Active)?;
        Ok(Router {
            active: RwLock::new(initial),
            paused: AtomicBool::new(false),
            in_downtime: AtomicBool::new(false),
            clock,
            stats: FrameStats::new(),
            latency: LatencyHistogram::new(true),
        })
    }

    pub fn active(&self) -> Arc<Pipeline> {
        self.active.read().unwrap().clone()
    }

    pub fn is_paused(&self) -> bool {
        self.paused.load(Ordering::Acquire)
    }

    pub fn set_downtime(&self, v: bool) {
        self.in_downtime.store(v, Ordering::Release);
    }

    pub fn in_downtime(&self) -> bool {
        self.in_downtime.load(Ordering::Acquire)
    }

    /// Route one frame to the active pipeline.
    pub fn route(&self, frame: &Literal) -> Result<RouteOutcome> {
        self.stats.produced();
        if self.is_paused() {
            self.stats.dropped(self.in_downtime());
            return Ok(RouteOutcome::DroppedPaused);
        }
        let pipeline = self.active();
        let report = pipeline.infer(frame)?;
        self.latency.record(report.total());
        self.stats.processed();
        Ok(RouteOutcome::Processed(report))
    }

    /// Route a burst of frames with edge/cloud overlap (the
    /// [`PipelinedRunner`] path). The active pipeline is pinned for the
    /// whole burst — a concurrent switch takes effect at the next call —
    /// and per-frame stats/latency are recorded exactly as [`Self::route`]
    /// does. While paused, every frame in the burst is dropped.
    pub fn route_batch(
        &self,
        frames: &[Literal],
        runner: PipelinedRunner,
    ) -> Result<Vec<RouteOutcome>> {
        if self.is_paused() {
            let mut out = Vec::with_capacity(frames.len());
            for _ in frames {
                self.stats.produced();
                self.stats.dropped(self.in_downtime());
                out.push(RouteOutcome::DroppedPaused);
            }
            return Ok(out);
        }
        for _ in frames {
            self.stats.produced();
        }
        let pipeline = self.active();
        let reports = runner.run(&pipeline, frames)?;
        let mut out = Vec::with_capacity(reports.len());
        for report in reports {
            self.latency.record(report.total());
            self.stats.processed();
            out.push(RouteOutcome::Processed(report));
        }
        Ok(out)
    }

    /// [`Self::route_batch`] with the default runner: three-stage overlap
    /// (edge | transfer | cloud) at [`DEFAULT_DEPTH`](super::runner::DEFAULT_DEPTH).
    pub fn route_burst(&self, frames: &[Literal]) -> Result<Vec<RouteOutcome>> {
        self.route_batch(frames, PipelinedRunner::default())
    }

    /// Atomically redirect traffic to `new` (Dynamic Switching's
    /// `t_switch`). The old pipeline is moved to Draining and returned so
    /// the strategy can retire or recycle it. Returns the measured switch
    /// time on the experiment clock.
    pub fn switch(&self, new: Arc<Pipeline>) -> Result<(Arc<Pipeline>, Duration)> {
        let t0 = self.clock.now();
        match new.state() {
            PipelineState::Initialising | PipelineState::Standby => {
                new.transition(PipelineState::Active)?
            }
            PipelineState::Active => {}
            s => anyhow::bail!("cannot switch to a pipeline in state {s}"),
        }
        let old = {
            let mut guard = self.active.write().unwrap();
            std::mem::replace(&mut *guard, new)
        };
        old.transition(PipelineState::Draining)?;
        Ok((old, self.clock.now() - t0))
    }

    /// Baseline pause: stop processing entirely.
    pub fn pause(&self) -> Result<()> {
        self.active().transition(PipelineState::Paused)?;
        self.paused.store(true, Ordering::Release);
        Ok(())
    }

    /// Baseline resume, optionally with a rebuilt pipeline (the updated
    /// metadata of §III-A step iv).
    pub fn resume(&self, replacement: Option<Arc<Pipeline>>) -> Result<()> {
        match replacement {
            Some(p) => {
                p.transition(PipelineState::Active)?;
                let old = {
                    let mut guard = self.active.write().unwrap();
                    std::mem::replace(&mut *guard, p)
                };
                old.transition(PipelineState::Terminated)?;
            }
            None => {
                self.active().transition(PipelineState::Active)?;
            }
        }
        self.paused.store(false, Ordering::Release);
        Ok(())
    }
}
