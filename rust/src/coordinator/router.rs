//! Request router: directs device frames to the active pipeline and
//! implements the atomic switch at the heart of Dynamic Switching.
//!
//! The switch is an `Arc` pointer swap under an `RwLock` — the measured
//! `t_switch` of Equation 3. During a baseline pause the router drops
//! every frame (the paper: "no frames sent from the device to the edge
//! will be processed"); during a Dynamic Switching window frames keep
//! flowing to the old pipeline at degraded quality.
//!
//! Fault tolerance (§III-B's "degraded until switch", made literal):
//! when a frame's uplink transfer exhausts its retries
//! ([`TransferAborted`]), the frame is dropped and — if a full-model
//! fallback pipeline is armed via [`Router::arm_degraded`] — the router
//! enters a *degraded window*, answering subsequent frames edge-only
//! until a successful [`Router::switch`] ends it. Switches themselves
//! can roll back: [`Router::switch_probed`] probes the new pipeline
//! *before* the pointer swap, so a failed bring-up or probe leaves the
//! router on the old pipeline and only a [`FaultStats`] counter (and the
//! caller's `DowntimeRecord`) remembers the attempt.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::Result;
use xla::Literal;

use crate::clock::Clock;
use crate::metrics::{FaultStats, FrameStats, LatencyHistogram};
use crate::netsim::TransferAborted;
use crate::util::sync::{lock_clean, read_clean, write_clean};

use super::pipeline::{InferenceReport, Pipeline};
use super::runner::PipelinedRunner;
use super::state::PipelineState;

/// Outcome of routing one frame.
pub enum RouteOutcome {
    Processed(InferenceReport),
    /// Dropped because the router is paused (baseline downtime).
    DroppedPaused,
    /// Dropped because the transfer exhausted its retries/deadline.
    DroppedFaulted,
    /// Served edge-only by the degraded fallback pipeline.
    Degraded(InferenceReport),
}

/// Degraded-mode bookkeeping: the armed fallback and, while a window is
/// open, when it opened.
#[derive(Default)]
struct DegradedState {
    fallback: Option<Arc<Pipeline>>,
    since: Option<Duration>,
}

pub struct Router {
    active: RwLock<Arc<Pipeline>>,
    paused: AtomicBool,
    /// Set while a repartition window is open (frame-drop attribution).
    in_downtime: AtomicBool,
    degraded: Mutex<DegradedState>,
    pub clock: Clock,
    pub stats: FrameStats,
    pub latency: LatencyHistogram,
    /// Degraded-window and aborted-switch counters (router view; per-frame
    /// retry counters live on each pipeline's `fault_stats`).
    pub fault_stats: FaultStats,
}

impl Router {
    /// Create a router over an initial pipeline, activating it.
    pub fn new(clock: Clock, initial: Arc<Pipeline>) -> Result<Self> {
        initial.transition(PipelineState::Active)?;
        Ok(Router {
            active: RwLock::new(initial),
            paused: AtomicBool::new(false),
            in_downtime: AtomicBool::new(false),
            degraded: Mutex::new(DegradedState::default()),
            clock,
            stats: FrameStats::new(),
            latency: LatencyHistogram::new(true),
            fault_stats: FaultStats::new(),
        })
    }

    pub fn active(&self) -> Arc<Pipeline> {
        read_clean(&self.active).clone()
    }

    pub fn is_paused(&self) -> bool {
        self.paused.load(Ordering::Acquire)
    }

    pub fn set_downtime(&self, v: bool) {
        self.in_downtime.store(v, Ordering::Release);
    }

    pub fn in_downtime(&self) -> bool {
        self.in_downtime.load(Ordering::Acquire)
    }

    /// Arm the degraded fallback: a full-model-on-the-edge pipeline
    /// (empty cloud chain) held in `Standby`, serving edge-only frames
    /// whenever retry exhaustion opens a degraded window.
    pub fn arm_degraded(&self, fallback: Arc<Pipeline>) -> Result<()> {
        anyhow::ensure!(
            fallback.cloud_chain.is_empty(),
            "degraded fallback must hold the full model on the edge \
             (pipeline {} has a non-empty cloud chain)",
            fallback.id,
        );
        if fallback.state() == PipelineState::Initialising {
            fallback.transition(PipelineState::Standby)?;
        }
        lock_clean(&self.degraded).fallback = Some(fallback);
        Ok(())
    }

    /// The armed fallback, if any.
    pub fn degraded_pipeline(&self) -> Option<Arc<Pipeline>> {
        lock_clean(&self.degraded).fallback.clone()
    }

    /// Whether a degraded window is currently open.
    pub fn in_degraded(&self) -> bool {
        lock_clean(&self.degraded).since.is_some()
    }

    /// Open a degraded window (idempotent while one is open).
    fn enter_degraded(&self) {
        let mut d = lock_clean(&self.degraded);
        if d.since.is_none() {
            d.since = Some(self.clock.now());
        }
    }

    /// Close the degraded window, crediting its duration to the stats.
    fn exit_degraded(&self) {
        let since = lock_clean(&self.degraded).since.take();
        if let Some(t0) = since {
            self.fault_stats.record_degraded_window(self.clock.now() - t0);
        }
    }

    /// Route one frame to the active pipeline — or, inside a degraded
    /// window, edge-only to the fallback.
    pub fn route(&self, frame: &Literal) -> Result<RouteOutcome> {
        self.stats.produced();
        if self.is_paused() {
            self.stats.dropped(self.in_downtime());
            return Ok(RouteOutcome::DroppedPaused);
        }
        if self.in_degraded() {
            if let Some(fb) = self.degraded_pipeline() {
                let report = fb.infer_edge_only(frame)?;
                self.fault_stats.record_degraded_frame();
                self.latency.record(report.total());
                self.stats.processed();
                return Ok(RouteOutcome::Degraded(report));
            }
        }
        let pipeline = self.active();
        match pipeline.infer(frame) {
            Ok(report) => {
                self.latency.record(report.total());
                self.stats.processed();
                Ok(RouteOutcome::Processed(report))
            }
            // Retry exhaustion: this frame is lost either way; with a
            // fallback armed the *next* frames serve edge-only.
            Err(e) if e.downcast_ref::<TransferAborted>().is_some() => {
                self.stats.dropped(self.in_downtime());
                if self.degraded_pipeline().is_some() {
                    self.enter_degraded();
                }
                Ok(RouteOutcome::DroppedFaulted)
            }
            Err(e) => Err(e),
        }
    }

    /// Route a burst of frames with edge/cloud overlap (the
    /// [`PipelinedRunner`] path). The active pipeline is pinned for the
    /// whole burst — a concurrent switch takes effect at the next call —
    /// and per-frame stats/latency are recorded exactly as [`Self::route`]
    /// does. While paused, every frame in the burst is dropped. Frames
    /// the runner dropped on retry exhaustion surface as
    /// [`RouteOutcome::DroppedFaulted`] (appended after the processed
    /// reports, which stay in frame order) and open a degraded window
    /// when a fallback is armed.
    pub fn route_batch(
        &self,
        frames: &[Literal],
        runner: PipelinedRunner,
    ) -> Result<Vec<RouteOutcome>> {
        if self.is_paused() {
            let mut out = Vec::with_capacity(frames.len());
            for _ in frames {
                self.stats.produced();
                self.stats.dropped(self.in_downtime());
                out.push(RouteOutcome::DroppedPaused);
            }
            return Ok(out);
        }
        for _ in frames {
            self.stats.produced();
        }
        let pipeline = self.active();
        let reports = runner.run(&pipeline, frames)?;
        let dropped = frames.len() - reports.len();
        let mut out = Vec::with_capacity(frames.len());
        for report in reports {
            self.latency.record(report.total());
            self.stats.processed();
            out.push(RouteOutcome::Processed(report));
        }
        for _ in 0..dropped {
            self.stats.dropped(self.in_downtime());
            out.push(RouteOutcome::DroppedFaulted);
        }
        if dropped > 0 && self.degraded_pipeline().is_some() {
            self.enter_degraded();
        }
        Ok(out)
    }

    /// [`Self::route_batch`] with the default runner: three-stage overlap
    /// (edge | transfer | cloud) at [`DEFAULT_DEPTH`](super::runner::DEFAULT_DEPTH).
    pub fn route_burst(&self, frames: &[Literal]) -> Result<Vec<RouteOutcome>> {
        self.route_batch(frames, PipelinedRunner::default())
    }

    /// Atomically redirect traffic to `new` (Dynamic Switching's
    /// `t_switch`). The old pipeline is moved to Draining and returned so
    /// the strategy can retire or recycle it. A successful switch closes
    /// any open degraded window — the repartition is the cure. Returns
    /// the measured switch time on the experiment clock.
    pub fn switch(&self, new: Arc<Pipeline>) -> Result<(Arc<Pipeline>, Duration)> {
        let t0 = self.clock.now();
        match new.state() {
            PipelineState::Initialising | PipelineState::Standby => {
                new.transition(PipelineState::Active)?
            }
            PipelineState::Active => {}
            s => anyhow::bail!("cannot switch to a pipeline in state {s}"),
        }
        let old = {
            let mut guard = write_clean(&self.active);
            std::mem::replace(&mut *guard, new)
        };
        old.transition(PipelineState::Draining)?;
        self.exit_degraded();
        Ok((old, self.clock.now() - t0))
    }

    /// [`Self::switch`] with a probe-first guard (the rollback half of
    /// fault-tolerant switching): run one probe inference through `new`
    /// *before* the pointer swap. If the probe fails — a faulted link
    /// exhausting retries, a broken chain — the router is untouched, the
    /// old pipeline keeps serving, and the aborted switch is counted.
    /// The probe frame's cost lands on the experiment clock (it really
    /// ran), but never on the router's per-frame stats.
    pub fn switch_probed(
        &self,
        new: Arc<Pipeline>,
        probe: &Literal,
    ) -> Result<(Arc<Pipeline>, Duration)> {
        if let Err(e) = new.infer_unchecked(probe) {
            self.fault_stats.record_aborted_switch();
            return Err(e.context(format!(
                "probe inference failed on pipeline {}; switch rolled back, \
                 router stays on pipeline {}",
                new.id,
                self.active().id,
            )));
        }
        self.switch(new)
    }

    /// Baseline pause: stop processing entirely.
    pub fn pause(&self) -> Result<()> {
        self.active().transition(PipelineState::Paused)?;
        self.paused.store(true, Ordering::Release);
        Ok(())
    }

    /// Baseline resume, optionally with a rebuilt pipeline (the updated
    /// metadata of §III-A step iv).
    pub fn resume(&self, replacement: Option<Arc<Pipeline>>) -> Result<()> {
        match replacement {
            Some(p) => {
                p.transition(PipelineState::Active)?;
                let old = {
                    let mut guard = write_clean(&self.active);
                    std::mem::replace(&mut *guard, p)
                };
                old.transition(PipelineState::Terminated)?;
            }
            None => {
                self.active().transition(PipelineState::Active)?;
            }
        }
        self.paused.store(false, Ordering::Release);
        Ok(())
    }
}
