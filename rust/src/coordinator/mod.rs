//! L3 coordinator — the NEUKONFIG framework itself.
//!
//! * [`pipeline`] — the edge-cloud pipeline and its factory ([`pipeline::EdgeCloudEnv`]).
//! * [`router`] — frame routing + the atomic switch.
//! * [`monitor`] — network-speed watching and repartition triggers.
//! * [`planner`] — Equation-1 split planning from the layer profile.
//! * [`pause_resume`] — the baseline approach (§III-A).
//! * [`switching`] — Dynamic Switching, Scenario A/B x Case 1/2 (§III-B).
//! * [`runner`] — overlapped (pipelined) frame execution.
//! * [`batcher`] — the bounded edge frame queue.
//! * [`flow`] — frame-drop simulation during downtime windows (Figs 14/15).
//! * [`state`] — the pipeline lifecycle state machine.
//! * [`experiments`] — drivers that regenerate every paper figure/table.

pub mod batcher;
pub mod experiments;
pub mod flow;
pub mod monitor;
pub mod pause_resume;
pub mod pipeline;
pub mod planner;
pub mod router;
pub mod runner;
pub mod server;
pub mod state;
pub mod switching;

pub use monitor::{BandwidthChange, NetworkMonitor, TriggerPolicy};
pub use pause_resume::PauseResume;
pub use pipeline::{EdgeCloudEnv, InferenceReport, Pipeline, Placement, TransferReport};
pub use planner::{PartitionPlan, Planner};
pub use router::{RouteOutcome, Router};
pub use runner::{PipelinedRunner, StageMode};
pub use server::{serve, ServeReport, ServerConfig, Strategy};
pub use state::PipelineState;
pub use switching::{arm_degraded_fallback, PlacementCase, ScenarioA, ScenarioB};
