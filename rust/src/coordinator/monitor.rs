//! Network-speed monitor: watches the shaped link, applies the bandwidth
//! trace, and raises repartition events when the speed changes.
//!
//! This is NEUKONFIG's "identify new metadata" trigger (§III): variation
//! in network speed is the validated repartitioning scenario (§II-B; CPU
//! and memory stress were shown *not* to move the split).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::netsim::{Link, Schedule};
use crate::util::sync::lock_clean;

/// A detected change in network speed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthChange {
    pub at: Duration,
    pub from_mbps: f64,
    pub to_mbps: f64,
}

pub struct NetworkMonitor {
    link: Arc<Link>,
    schedule: Mutex<Schedule>,
    last_mbps: Mutex<f64>,
    /// Relative change that counts as a repartition trigger (e.g. 0.2 =
    /// 20 %); tiny jitter is ignored.
    pub threshold: f64,
}

impl NetworkMonitor {
    pub fn new(link: Arc<Link>, schedule: Schedule) -> Self {
        let last = link.bandwidth_mbps();
        NetworkMonitor {
            link,
            schedule: Mutex::new(schedule),
            last_mbps: Mutex::new(last),
            threshold: 0.2,
        }
    }

    /// Advance the trace to `now` (applying due bandwidth events to the
    /// link) and report a change if it crosses the threshold.
    pub fn poll(&self, now: Duration) -> Option<BandwidthChange> {
        if let Some(new_bw) = lock_clean(&self.schedule).poll(now) {
            self.link.set_bandwidth(new_bw);
        }
        let current = self.link.bandwidth_mbps();
        let mut last = lock_clean(&self.last_mbps);
        let rel = (current - *last).abs() / last.max(1e-9);
        if rel > self.threshold {
            let change = BandwidthChange { at: now, from_mbps: *last, to_mbps: current };
            *last = current;
            Some(change)
        } else {
            None
        }
    }

    /// Snapshot the watched link's injected-fault counters (chunks lost,
    /// spiked, aborted attempts) — the monitor is the natural reporting
    /// point for link health next to bandwidth.
    pub fn fault_counters(&self) -> crate::netsim::LinkFaultCounters {
        self.link.fault_counters()
    }

    pub fn next_event(&self) -> Option<(Duration, f64)> {
        lock_clean(&self.schedule).peek_next()
    }

    pub fn trace_done(&self) -> bool {
        lock_clean(&self.schedule).is_done()
    }
}

/// Repartition-frequency policy (the paper's §VI future work: "how
/// frequently must the DNN be repartitioned").
///
/// Two guards against thrashing on a jittery link:
/// * **debounce** — a change must persist for `confirm_polls` consecutive
///   polls before it triggers (transient dips are ignored);
/// * **cooldown** — at most one repartition per `min_interval`.
#[derive(Debug)]
pub struct TriggerPolicy {
    pub min_interval: Duration,
    pub confirm_polls: u32,
    state: Mutex<PolicyState>,
}

#[derive(Debug, Default)]
struct PolicyState {
    pending: Option<BandwidthChange>,
    confirmations: u32,
    last_fire: Option<Duration>,
}

impl TriggerPolicy {
    pub fn new(min_interval: Duration, confirm_polls: u32) -> Self {
        TriggerPolicy {
            min_interval,
            confirm_polls,
            state: Mutex::new(PolicyState::default()),
        }
    }

    /// Immediate triggering (the paper's evaluated behaviour).
    pub fn immediate() -> Self {
        Self::new(Duration::ZERO, 0)
    }

    /// Feed one monitor poll result; returns the change once it survives
    /// the debounce + cooldown gates.
    pub fn filter(
        &self,
        now: Duration,
        observed: Option<BandwidthChange>,
    ) -> Option<BandwidthChange> {
        let mut s = lock_clean(&self.state);
        if let Some(change) = observed {
            // A new (different-target) change restarts confirmation.
            match s.pending {
                Some(p) if p.to_mbps == change.to_mbps => {}
                _ => s.confirmations = 0,
            }
            s.pending = Some(change);
        }
        let pending = s.pending?;
        s.confirmations += 1;
        if s.confirmations <= self.confirm_polls {
            return None;
        }
        if let Some(last) = s.last_fire {
            if now < last + self.min_interval {
                return None; // still cooling down; keep pending
            }
        }
        s.pending = None;
        s.confirmations = 0;
        s.last_fire = Some(now);
        Some(pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::Clock;

    fn setup(events: Vec<(Duration, f64)>) -> (Arc<Link>, NetworkMonitor) {
        let link = Arc::new(Link::new(Clock::simulated(), 20.0, Duration::from_millis(20)));
        let mon = NetworkMonitor::new(link.clone(), Schedule::new(events));
        (link, mon)
    }

    #[test]
    fn detects_scheduled_drop() {
        let (link, mon) = setup(vec![(Duration::from_secs(5), 5.0)]);
        assert_eq!(mon.poll(Duration::from_secs(1)), None);
        let c = mon.poll(Duration::from_secs(5)).expect("change");
        assert_eq!(c.from_mbps, 20.0);
        assert_eq!(c.to_mbps, 5.0);
        assert_eq!(link.bandwidth_mbps(), 5.0);
    }

    #[test]
    fn no_duplicate_events() {
        let (_, mon) = setup(vec![(Duration::from_secs(1), 5.0)]);
        assert!(mon.poll(Duration::from_secs(2)).is_some());
        assert!(mon.poll(Duration::from_secs(3)).is_none());
    }

    #[test]
    fn ignores_sub_threshold_jitter() {
        let (_, mon) = setup(vec![(Duration::from_secs(1), 21.0)]);
        // 5% change < 20% threshold.
        assert!(mon.poll(Duration::from_secs(1)).is_none());
    }

    #[test]
    fn detects_external_change() {
        // Bandwidth changed directly on the link (not via the trace).
        let (link, mon) = setup(vec![]);
        link.set_bandwidth(5.0);
        let c = mon.poll(Duration::from_secs(1)).expect("change");
        assert_eq!(c.to_mbps, 5.0);
        assert!(mon.trace_done());
    }

    fn change(to: f64) -> BandwidthChange {
        BandwidthChange { at: Duration::ZERO, from_mbps: 20.0, to_mbps: to }
    }

    #[test]
    fn policy_immediate_passes_through() {
        let p = TriggerPolicy::immediate();
        assert_eq!(p.filter(Duration::ZERO, Some(change(5.0))), Some(change(5.0)));
    }

    #[test]
    fn policy_debounce_requires_confirmations() {
        let p = TriggerPolicy::new(Duration::ZERO, 2);
        let t = Duration::from_secs;
        assert_eq!(p.filter(t(0), Some(change(5.0))), None);
        assert_eq!(p.filter(t(1), None), None); // 2nd confirmation
        assert_eq!(p.filter(t(2), None), Some(change(5.0))); // survives
    }

    #[test]
    fn policy_transient_dip_resets() {
        let p = TriggerPolicy::new(Duration::ZERO, 2);
        let t = Duration::from_secs;
        assert_eq!(p.filter(t(0), Some(change(5.0))), None);
        // Link recovers: a different change target restarts confirmation.
        assert_eq!(p.filter(t(1), Some(change(20.0))), None);
        assert_eq!(p.filter(t(2), None), None);
        assert_eq!(p.filter(t(3), None), Some(change(20.0)));
    }

    #[test]
    fn policy_cooldown_rate_limits() {
        let p = TriggerPolicy::new(Duration::from_secs(10), 0);
        let t = Duration::from_secs;
        assert_eq!(p.filter(t(0), Some(change(5.0))), Some(change(5.0)));
        // Second change arrives inside the cooldown: held, not dropped.
        assert_eq!(p.filter(t(3), Some(change(20.0))), None);
        assert_eq!(p.filter(t(11), None), Some(change(20.0)));
    }

    #[test]
    fn rise_and_drop_both_detected() {
        let (_, mon) = setup(vec![
            (Duration::from_secs(1), 5.0),
            (Duration::from_secs(2), 20.0),
        ]);
        assert_eq!(mon.poll(Duration::from_secs(1)).unwrap().to_mbps, 5.0);
        assert_eq!(mon.poll(Duration::from_secs(2)).unwrap().to_mbps, 20.0);
    }
}
