//! NEUKONFIG CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; no clap offline):
//!
//! ```text
//! neukonfig profile  [--model vgg19|mobilenetv2] [--reps N]
//! neukonfig sweep    [--model M] [--bw MBPS]         # Fig 2/3 rows
//! neukonfig downtime [--model M] --approach A [--to-low|--to-high]
//! neukonfig table1   [--model M]                     # Table I
//! neukonfig info                                     # artifact inventory
//! ```

use std::process::ExitCode;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use neukonfig::coordinator::experiments::{
    downtime_grid, partition_sweep, split_pair, table1_memory, Approach, ExperimentSetup,
};
use neukonfig::coordinator::PlacementCase;
use neukonfig::metrics::{fmt_duration, Table};
use neukonfig::models::default_artifacts_dir;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:?}");
            ExitCode::FAILURE
        }
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    flags.push((key.to_string(), rest[i + 1].clone()));
                    i += 2;
                } else {
                    switches.push(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?}");
            }
        }
        Ok(Args { cmd, flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    let model = args.get("model").unwrap_or("vgg19").to_string();

    match args.cmd.as_str() {
        "info" => info(),
        "profile" => profile(&model, args.get("reps").map_or(3, |r| r.parse().unwrap_or(3))),
        "sweep" => {
            let bw: f64 = args.get("bw").map_or(20.0, |b| b.parse().unwrap_or(20.0));
            sweep(&model, bw)
        }
        "downtime" => {
            let approach = parse_approach(args.get("approach").unwrap_or("pause-resume"))?;
            downtime(&model, approach, !args.has("to-high"), args.has("no-sim-costs"))
        }
        "table1" => table1(&model),
        "serve" => {
            let strategy = args.get("strategy").unwrap_or("scenario-a-case2").to_string();
            let fps: f64 = args.get("fps").map_or(15.0, |v| v.parse().unwrap_or(15.0));
            let secs: u64 = args.get("seconds").map_or(15, |v| v.parse().unwrap_or(15));
            let period: u64 = args.get("period-s").map_or(5, |v| v.parse().unwrap_or(5));
            serve_cmd(&model, &strategy, fps, secs, period)
        }
        "help" | _ => {
            println!(
                "neukonfig — reducing edge service downtime when repartitioning DNNs\n\n\
                 usage: neukonfig <info|profile|sweep|downtime|table1|serve> [--model vgg19|mobilenetv2]\n\
                 serve flags: --strategy <name> --fps N --seconds N --period-s N\n\
                 see README.md for details"
            );
            Ok(())
        }
    }
}

fn serve_cmd(model: &str, strategy: &str, fps: f64, secs: u64, period: u64) -> Result<()> {
    use neukonfig::clock::Clock;
    use neukonfig::coordinator::server::{serve, ServerConfig, Strategy};
    use neukonfig::coordinator::{EdgeCloudEnv, NetworkMonitor, Planner, TriggerPolicy};
    use neukonfig::netsim::Schedule;
    use std::sync::Arc;

    let setup = ExperimentSetup::load()?;
    let manifest = setup.manifest(model)?;
    let env = Arc::new(EdgeCloudEnv::new(setup.cfg.clone(), manifest, Clock::realtime())?);
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let planner = Planner::new(profile, setup.cfg.network.latency);
    let hi = planner.plan(setup.cfg.network.high_mbps).split;
    let lo = planner.plan(setup.cfg.network.low_mbps).split;

    eprintln!("deploying {strategy} (splits {hi}<->{lo})...");
    let strat = Strategy::deploy(strategy, env.clone(), hi, lo)?;
    let monitor = NetworkMonitor::new(
        env.link.clone(),
        Schedule::toggle(
            setup.cfg.network.high_mbps,
            setup.cfg.network.low_mbps,
            Duration::from_secs(period),
            (secs / period.max(1)) as usize,
        ),
    );
    let report = serve(
        &strat,
        &env,
        &monitor,
        &planner,
        ServerConfig {
            fps,
            run_for: Duration::from_secs(secs),
            policy: TriggerPolicy::immediate(),
            ..Default::default()
        },
    )?;

    let router = strat.router();
    let s = router.stats.snapshot();
    println!("served {:.1}s: {} produced, {} processed, {} dropped",
        report.elapsed.as_secs_f64(), s.produced, s.processed, s.dropped);
    for (i, d) in report.downtimes.iter().enumerate() {
        println!(
            "repartition {} -> split {} @ {} Mbps: downtime {} (real {}, sim {})",
            i + 1,
            report.repartitions[i].1,
            report.repartitions[i].0,
            fmt_duration(d.total),
            fmt_duration(d.real()),
            fmt_duration(d.simulated)
        );
    }
    if let Some(sum) = router.latency.summary() {
        println!(
            "latency mean {} p95 {}",
            fmt_duration(Duration::from_secs_f64(sum.mean)),
            fmt_duration(Duration::from_secs_f64(sum.p95))
        );
    }
    Ok(())
}

fn parse_approach(s: &str) -> Result<Approach> {
    Ok(match s {
        "pause-resume" => Approach::PauseResume,
        "scenario-a-case1" => Approach::ScenarioA(PlacementCase::NewContainer),
        "scenario-a-case2" => Approach::ScenarioA(PlacementCase::SameContainer),
        "scenario-b-case1" => Approach::ScenarioB(PlacementCase::NewContainer),
        "scenario-b-case2" => Approach::ScenarioB(PlacementCase::SameContainer),
        other => bail!("unknown approach {other:?}"),
    })
}

fn info() -> Result<()> {
    let dir = default_artifacts_dir();
    let setup = ExperimentSetup::load().context("loading artifacts")?;
    println!("artifacts: {}", dir.display());
    println!("width={} input={}px", setup.index.width, setup.index.hw);
    for name in &setup.index.models {
        let m = setup.manifest(name)?;
        println!(
            "  {name}: {} units, {:.1} MB weights, {:.1} MFLOP",
            m.num_layers(),
            m.weights_bytes as f64 / 1e6,
            m.total_flops as f64 / 1e6
        );
    }
    Ok(())
}

fn profile(model: &str, reps: usize) -> Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env(model)?;
    let prof = setup.measured_profile(&env, reps)?;
    let mut t = Table::new(
        &format!("{model} per-layer profile"),
        &["#", "layer", "kind", "edge", "cloud", "out KB"],
    );
    for l in &prof.layers {
        t.row(vec![
            l.index.to_string(),
            l.name.clone(),
            l.kind.clone(),
            fmt_duration(l.edge_time),
            fmt_duration(l.cloud_time),
            format!("{:.1}", l.output_bytes as f64 / 1024.0),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn sweep(model: &str, bw: f64) -> Result<()> {
    let setup = ExperimentSetup::load()?;
    let env = setup.env(model)?;
    let prof = setup.measured_profile(&env, 3)?;
    let rows = partition_sweep(&prof, bw, setup.cfg.network.latency);
    let mut t = Table::new(
        &format!("{model} partition sweep @ {bw} Mbps (Fig 2/3)"),
        &["split", "layer", "edge", "transfer", "cloud", "total", "out KB", "opt"],
    );
    for r in rows {
        t.row(vec![
            r.split.to_string(),
            r.layer,
            fmt_duration(Duration::from_secs_f64(r.edge_s)),
            fmt_duration(Duration::from_secs_f64(r.transfer_s)),
            fmt_duration(Duration::from_secs_f64(r.cloud_s)),
            fmt_duration(Duration::from_secs_f64(r.total_s)),
            format!("{:.1}", r.out_kb),
            if r.optimal { "*".into() } else { String::new() },
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn downtime(model: &str, approach: Approach, to_low: bool, no_sim: bool) -> Result<()> {
    let mut setup = ExperimentSetup::load()?;
    if no_sim {
        setup.cfg = setup.cfg.clone().without_sim_costs();
    }
    let env = setup.env(model)?;
    let prof = setup.measured_profile(&env, 2)?;
    let pair = split_pair(&prof, &setup.cfg);
    println!(
        "splits: {}@{}Mbps -> {}@{}Mbps",
        pair.at_high, setup.cfg.network.high_mbps, pair.at_low, setup.cfg.network.low_mbps
    );
    let (from, to) = if to_low {
        (setup.cfg.network.high_mbps, setup.cfg.network.low_mbps)
    } else {
        (setup.cfg.network.low_mbps, setup.cfg.network.high_mbps)
    };
    let cells = downtime_grid(&env, &prof, approach, from, to)?;
    let mut t = Table::new(
        &format!("{} downtime, {}->{} Mbps", approach.label(), from, to),
        &["cpu %", "mem %", "downtime", "real", "simulated"],
    );
    for c in cells {
        match c.downtime {
            Some(d) => t.row(vec![
                format!("{:.0}", c.cpu_avail * 100.0),
                format!("{:.0}", c.mem_avail * 100.0),
                fmt_duration(d.total),
                fmt_duration(d.real()),
                fmt_duration(d.simulated),
            ]),
            None => t.row(vec![
                format!("{:.0}", c.cpu_avail * 100.0),
                format!("{:.0}", c.mem_avail * 100.0),
                "OOM".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn table1(model: &str) -> Result<()> {
    let setup = ExperimentSetup::load()?;
    let rows = table1_memory(&setup, model)?;
    let mut t = Table::new(
        "Table I: memory required per approach",
        &["approach", "initial MB", "additional MB", "total peak MB", "transient"],
    );
    for r in rows {
        t.row(vec![
            r.approach.to_string(),
            format!("{:.1}", r.initial_mb),
            format!("{:.1}", r.additional_mb),
            format!("{:.1}", r.peak_mb),
            if r.transient { "yes (switching only)".into() } else { "no".into() },
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}
