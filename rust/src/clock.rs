//! Hybrid real/simulated clock.
//!
//! NEUKONFIG's downtime windows mix two kinds of cost:
//!
//! * **real work our system actually performs** — PJRT compilation of the
//!   partition executables, weight-literal upload, the router switch — which
//!   is measured with the monotonic wall clock; and
//! * **Docker control-plane costs from the paper's testbed** (container
//!   image start, pause/unpause, Keras model reload) that have no real
//!   counterpart here and are injected as calibrated *simulated* offsets
//!   (DESIGN.md §Substitutions).
//!
//! `Clock::now()` = real elapsed time + accumulated simulated offset, so a
//! downtime measured as `t1 - t0` transparently includes both. In
//! [`Mode::Realtime`] `sleep` genuinely sleeps (used by the live serving
//! example); in [`Mode::Simulated`] it advances the offset instead, letting
//! grid sweeps over 40+ configurations run in seconds while preserving the
//! real component of every measurement.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// `sleep` blocks the calling thread (live serving).
    Realtime,
    /// `sleep` advances the simulated offset (experiment sweeps).
    Simulated,
}

/// Shareable clock handle. Cloning shares the timeline.
#[derive(Debug, Clone)]
pub struct Clock {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    anchor: Instant,
    sim_offset_ns: AtomicU64,
    mode: Mode,
}

impl Clock {
    pub fn realtime() -> Self {
        Self::with_mode(Mode::Realtime)
    }

    pub fn simulated() -> Self {
        Self::with_mode(Mode::Simulated)
    }

    pub fn with_mode(mode: Mode) -> Self {
        Clock {
            inner: Arc::new(Inner {
                anchor: Instant::now(),
                sim_offset_ns: AtomicU64::new(0),
                mode,
            }),
        }
    }

    pub fn mode(&self) -> Mode {
        self.inner.mode
    }

    /// Time on this clock's timeline (real elapsed + simulated offset).
    pub fn now(&self) -> Duration {
        self.inner.anchor.elapsed()
            + Duration::from_nanos(self.inner.sim_offset_ns.load(Ordering::Relaxed))
    }

    /// Inject a simulated cost (always advances the offset, in both modes).
    pub fn advance(&self, d: Duration) {
        self.inner
            .sim_offset_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Wait for `d` on this timeline: real sleep in Realtime mode, offset
    /// advance in Simulated mode.
    pub fn sleep(&self, d: Duration) {
        match self.inner.mode {
            Mode::Realtime => std::thread::sleep(d),
            Mode::Simulated => self.advance(d),
        }
    }

    /// Total simulated component accumulated so far (for reporting the
    /// real/simulated split of a downtime figure).
    pub fn simulated_component(&self) -> Duration {
        Duration::from_nanos(self.inner.sim_offset_ns.load(Ordering::Relaxed))
    }
}

/// Measure `f` on clock `c`, returning (result, duration on the timeline).
pub fn timed<T>(c: &Clock, f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = c.now();
    let out = f();
    (out, c.now() - t0)
}

/// Monotonic stopwatch over the host clock — the sanctioned entry point for
/// timing *real measured work* (PJRT compilation, chain execution, codec
/// encode/decode, bench iterations), complementing [`Clock`], which owns the
/// experiment timeline.
///
/// Everything outside this module goes through `Stopwatch` or [`Clock`]
/// rather than calling `Instant::now()` directly: the fault/bandwidth
/// schedules and every downtime equation consume the virtual timeline, so a
/// stray wall-clock read is a determinism hazard the `neukonfig_lint`
/// `wall_clock` rule rejects.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    /// Wall time elapsed since [`Self::start`].
    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_moves_time() {
        let c = Clock::simulated();
        let t0 = c.now();
        c.advance(Duration::from_secs(5));
        assert!(c.now() - t0 >= Duration::from_secs(5));
    }

    #[test]
    fn sim_sleep_does_not_block() {
        let c = Clock::simulated();
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(1));
        assert!(c.simulated_component() >= Duration::from_secs(3600));
    }

    #[test]
    fn realtime_sleep_blocks() {
        let c = Clock::realtime();
        let t0 = c.now();
        c.sleep(Duration::from_millis(20));
        assert!(c.now() - t0 >= Duration::from_millis(20));
        assert_eq!(c.simulated_component(), Duration::ZERO);
    }

    #[test]
    fn clones_share_timeline() {
        let a = Clock::simulated();
        let b = a.clone();
        b.advance(Duration::from_secs(9));
        assert!(a.simulated_component() >= Duration::from_secs(9));
    }

    #[test]
    fn timed_includes_sim_cost() {
        let c = Clock::simulated();
        let (_, d) = timed(&c, || c.sleep(Duration::from_secs(2)));
        assert!(d >= Duration::from_secs(2));
    }

    #[test]
    fn stopwatch_measures_wall_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        let d = sw.elapsed();
        assert!(d >= Duration::from_millis(5));
        // Monotone: a later read never goes backwards.
        assert!(sw.elapsed() >= d);
    }

    #[test]
    fn now_is_monotone() {
        let c = Clock::simulated();
        let mut prev = c.now();
        for _ in 0..1000 {
            let t = c.now();
            assert!(t >= prev);
            prev = t;
        }
    }
}
