//! Metrics: downtime records, frame accounting, latency histograms, and
//! markdown table rendering for the experiment reports.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::Summary;
use crate::util::sync::lock_clean;

/// A measured service-downtime window, decomposed the way DESIGN.md
//  §Substitutions promises: real work vs simulated Docker offsets.
#[derive(Debug, Clone, Default)]
pub struct DowntimeRecord {
    /// Total downtime on the experiment timeline.
    pub total: Duration,
    /// Simulated (container control-plane) component.
    pub simulated: Duration,
    /// Named phases, in order (e.g. "pause", "rebuild-edge", "switch").
    pub phases: Vec<(String, Duration)>,
    /// True when the switch this record describes was rolled back — the
    /// router stayed on (or reverted to) the old pipeline and the time
    /// above bought nothing but the failed bring-up/probe.
    pub aborted: bool,
}

impl DowntimeRecord {
    pub fn real(&self) -> Duration {
        self.total.saturating_sub(self.simulated)
    }

    pub fn phase(&self, name: &str) -> Option<Duration> {
        self.phases
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
    }

    pub fn push_phase(&mut self, name: impl Into<String>, d: Duration) {
        self.phases.push((name.into(), d));
    }

    /// Record a chain's per-layer timings as one phase per layer, named
    /// `"<stage>/layer<manifest index>"` — e.g. a cloud chain starting at
    /// split 3 records `cloud/layer3`, `cloud/layer4`, ... Keeps the flat
    /// `(name, duration)` shape so existing report renderers show them
    /// unchanged.
    pub fn push_layer_phases(
        &mut self,
        stage: &str,
        first_layer: usize,
        per_layer: &[Duration],
    ) {
        for (j, d) in per_layer.iter().enumerate() {
            self.phases.push((format!("{stage}/layer{}", first_layer + j), *d));
        }
    }

    /// Sum of every phase whose name starts with `prefix` (e.g.
    /// `"cloud/"` totals the cloud chain's per-layer phases).
    pub fn phase_prefix_total(&self, prefix: &str) -> Duration {
        self.phases
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(_, d)| *d)
            .sum()
    }
}

/// Frame accounting over an experiment run.
#[derive(Debug, Default)]
pub struct FrameStats {
    inner: Mutex<FrameStatsInner>,
}

#[derive(Debug, Default, Clone)]
pub struct FrameStatsInner {
    pub produced: u64,
    pub processed: u64,
    pub dropped: u64,
    /// Frames dropped specifically inside a downtime window.
    pub dropped_during_downtime: u64,
}

impl FrameStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn produced(&self) {
        lock_clean(&self.inner).produced += 1;
    }

    pub fn processed(&self) {
        lock_clean(&self.inner).processed += 1;
    }

    pub fn dropped(&self, during_downtime: bool) {
        let mut s = lock_clean(&self.inner);
        s.dropped += 1;
        if during_downtime {
            s.dropped_during_downtime += 1;
        }
    }

    pub fn snapshot(&self) -> FrameStatsInner {
        lock_clean(&self.inner).clone()
    }
}

impl FrameStatsInner {
    /// Drop rate over all produced frames.
    pub fn drop_rate(&self) -> f64 {
        if self.produced == 0 {
            0.0
        } else {
            self.dropped as f64 / self.produced as f64
        }
    }
}

/// Transfer-codec accounting over a pipeline's lifetime: frames shipped,
/// raw vs wire bytes, and the host time spent encoding/decoding. The
/// effective compression ratio is the memory-vs-downtime knob's receipt —
/// what the uplink was actually spared.
#[derive(Debug, Default)]
pub struct CodecStats {
    inner: Mutex<CodecStatsInner>,
}

#[derive(Debug, Default, Clone)]
pub struct CodecStatsInner {
    pub frames: u64,
    pub raw_bytes: u64,
    pub wire_bytes: u64,
    pub encode_time: Duration,
    pub decode_time: Duration,
}

impl CodecStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, raw_bytes: usize, wire_bytes: usize, encode: Duration, decode: Duration) {
        let mut s = lock_clean(&self.inner);
        s.frames += 1;
        s.raw_bytes += raw_bytes as u64;
        s.wire_bytes += wire_bytes as u64;
        s.encode_time += encode;
        s.decode_time += decode;
    }

    pub fn snapshot(&self) -> CodecStatsInner {
        lock_clean(&self.inner).clone()
    }
}

impl CodecStatsInner {
    /// `raw / wire` over everything shipped (1.0 when nothing shipped).
    pub fn compression_ratio(&self) -> f64 {
        if self.wire_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.wire_bytes as f64
        }
    }

    /// Mean per-frame codec overhead (encode + decode).
    pub fn mean_codec_time(&self) -> Duration {
        if self.frames == 0 {
            Duration::ZERO
        } else {
            (self.encode_time + self.decode_time) / self.frames as u32
        }
    }
}

/// Fault-tolerance accounting: what the retry/degradation machinery
/// actually did. Pipelines count retries, backoff and dropped frames
/// (the Fig. 14/15 frame-drop regime); the router adds degraded-window
/// durations and aborted switches. Same mutex-over-inner shape as
/// [`CodecStats`]; stage threads record into it, so the lock recovers
/// from poison.
#[derive(Debug, Default)]
pub struct FaultStats {
    inner: Mutex<FaultStatsInner>,
}

#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct FaultStatsInner {
    /// Transfer attempts beyond each frame's first.
    pub retries: u64,
    /// Time spent sleeping between attempts (not link time).
    pub backoff_time: Duration,
    /// Frames abandoned after retries/deadline exhausted.
    pub dropped_frames: u64,
    /// Degraded (edge-only) windows entered.
    pub degraded_windows: u64,
    /// Total time spent serving degraded.
    pub degraded_time: Duration,
    /// Frames answered edge-only while degraded.
    pub degraded_frames: u64,
    /// Switches rolled back after a failed bring-up or probe.
    pub aborted_switches: u64,
}

impl FaultStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_retry(&self, backoff: Duration) {
        let mut s = lock_clean(&self.inner);
        s.retries += 1;
        s.backoff_time += backoff;
    }

    pub fn record_dropped_frame(&self) {
        lock_clean(&self.inner).dropped_frames += 1;
    }

    pub fn record_degraded_window(&self, lasted: Duration) {
        let mut s = lock_clean(&self.inner);
        s.degraded_windows += 1;
        s.degraded_time += lasted;
    }

    pub fn record_degraded_frame(&self) {
        lock_clean(&self.inner).degraded_frames += 1;
    }

    pub fn record_aborted_switch(&self) {
        lock_clean(&self.inner).aborted_switches += 1;
    }

    pub fn snapshot(&self) -> FaultStatsInner {
        lock_clean(&self.inner).clone()
    }
}

impl FaultStatsInner {
    /// Whether the fault machinery fired at all — a clean run keeps this
    /// false, which the no-fault identity tests pin.
    pub fn any(&self) -> bool {
        *self != FaultStatsInner::default()
    }

    /// Fold another snapshot in (pipeline + router views combine into
    /// one report line).
    pub fn merged(&self, other: &FaultStatsInner) -> FaultStatsInner {
        FaultStatsInner {
            retries: self.retries + other.retries,
            backoff_time: self.backoff_time + other.backoff_time,
            dropped_frames: self.dropped_frames + other.dropped_frames,
            degraded_windows: self.degraded_windows + other.degraded_windows,
            degraded_time: self.degraded_time + other.degraded_time,
            degraded_frames: self.degraded_frames + other.degraded_frames,
            aborted_switches: self.aborted_switches + other.aborted_switches,
        }
    }
}

/// Log-bucketed latency histogram (1 us .. ~100 s), lock-free enough for
/// the request path via a mutex over u64 buckets (contention is per-frame,
/// far below PJRT execution cost).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Mutex<Vec<u64>>,
    samples: Mutex<Vec<f64>>,
    keep_samples: bool,
}

const BUCKETS_PER_DECADE: usize = 10;
const DECADES: usize = 8; // 1us .. 100s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new(true)
    }
}

impl LatencyHistogram {
    pub fn new(keep_samples: bool) -> Self {
        LatencyHistogram {
            buckets: Mutex::new(vec![0; BUCKETS_PER_DECADE * DECADES + 1]),
            samples: Mutex::new(Vec::new()),
            keep_samples,
        }
    }

    fn bucket_of(d: Duration) -> usize {
        let us = d.as_secs_f64() * 1e6;
        if us < 1.0 {
            return 0;
        }
        let idx = (us.log10() * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(BUCKETS_PER_DECADE * DECADES)
    }

    pub fn record(&self, d: Duration) {
        let idx = Self::bucket_of(d);
        lock_clean(&self.buckets)[idx] += 1;
        if self.keep_samples {
            lock_clean(&self.samples).push(d.as_secs_f64());
        }
    }

    pub fn count(&self) -> u64 {
        lock_clean(&self.buckets).iter().sum()
    }

    /// Exact summary when samples are kept, else None.
    pub fn summary(&self) -> Option<Summary> {
        let s = lock_clean(&self.samples);
        Summary::of(&s)
    }

    /// Approximate quantile from the histogram buckets (upper bound of the
    /// bucket containing the quantile).
    pub fn quantile_approx(&self, q: f64) -> Option<Duration> {
        let buckets = lock_clean(&self.buckets);
        let total: u64 = buckets.iter().sum();
        if total == 0 {
            return None;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let upper_us = 10f64.powf((i + 1) as f64 / BUCKETS_PER_DECADE as f64);
                return Some(Duration::from_secs_f64(upper_us / 1e6));
            }
        }
        None
    }
}

/// Markdown table builder for experiment reports.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.headers.len())
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

/// Human-friendly duration rendering for reports.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.1} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn downtime_decomposition() {
        let mut d = DowntimeRecord {
            total: Duration::from_millis(700),
            simulated: Duration::from_millis(300),
            ..DowntimeRecord::default()
        };
        d.push_phase("pause", Duration::from_millis(300));
        d.push_phase("rebuild", Duration::from_millis(400));
        assert_eq!(d.real(), Duration::from_millis(400));
        assert_eq!(d.phase("pause"), Some(Duration::from_millis(300)));
        assert_eq!(d.phase("nope"), None);
    }

    #[test]
    fn layer_phases_named_by_manifest_index() {
        let mut d = DowntimeRecord::default();
        d.push_layer_phases(
            "edge",
            0,
            &[Duration::from_millis(2), Duration::from_millis(3)],
        );
        d.push_layer_phases(
            "cloud",
            2,
            &[Duration::from_millis(5), Duration::from_millis(7)],
        );
        assert_eq!(d.phase("edge/layer0"), Some(Duration::from_millis(2)));
        assert_eq!(d.phase("edge/layer1"), Some(Duration::from_millis(3)));
        assert_eq!(d.phase("cloud/layer2"), Some(Duration::from_millis(5)));
        assert_eq!(d.phase("cloud/layer3"), Some(Duration::from_millis(7)));
        assert_eq!(d.phase_prefix_total("edge/"), Duration::from_millis(5));
        assert_eq!(d.phase_prefix_total("cloud/"), Duration::from_millis(12));
        assert_eq!(d.phase_prefix_total("nope/"), Duration::ZERO);
    }

    #[test]
    fn codec_stats_accumulate() {
        let c = CodecStats::new();
        assert_eq!(c.snapshot().compression_ratio(), 1.0);
        assert_eq!(c.snapshot().mean_codec_time(), Duration::ZERO);
        c.record(4000, 1016, Duration::from_micros(30), Duration::from_micros(50));
        c.record(4000, 1016, Duration::from_micros(10), Duration::from_micros(30));
        let s = c.snapshot();
        assert_eq!(s.frames, 2);
        assert_eq!(s.raw_bytes, 8000);
        assert_eq!(s.wire_bytes, 2032);
        assert!((s.compression_ratio() - 8000.0 / 2032.0).abs() < 1e-12);
        assert_eq!(s.mean_codec_time(), Duration::from_micros(60));
    }

    #[test]
    fn fault_stats_accumulate_and_merge() {
        let f = FaultStats::new();
        assert!(!f.snapshot().any(), "fresh stats are clean");
        f.record_retry(Duration::from_millis(25));
        f.record_retry(Duration::from_millis(50));
        f.record_dropped_frame();
        f.record_degraded_window(Duration::from_millis(400));
        f.record_degraded_frame();
        f.record_aborted_switch();
        let s = f.snapshot();
        assert!(s.any());
        assert_eq!(s.retries, 2);
        assert_eq!(s.backoff_time, Duration::from_millis(75));
        assert_eq!(s.dropped_frames, 1);
        assert_eq!(s.degraded_windows, 1);
        assert_eq!(s.degraded_time, Duration::from_millis(400));
        assert_eq!(s.degraded_frames, 1);
        assert_eq!(s.aborted_switches, 1);
        let m = s.merged(&s);
        assert_eq!(m.retries, 4);
        assert_eq!(m.backoff_time, Duration::from_millis(150));
        assert_eq!(m.aborted_switches, 2);
    }

    #[test]
    fn downtime_record_marks_aborted_switches() {
        let mut d = DowntimeRecord::default();
        assert!(!d.aborted, "default record is a landed switch");
        d.aborted = true;
        d.push_phase("aborted-bringup", Duration::from_millis(100));
        assert_eq!(d.phase("aborted-bringup"), Some(Duration::from_millis(100)));
    }

    #[test]
    fn frame_stats_counts() {
        let f = FrameStats::new();
        for _ in 0..10 {
            f.produced();
        }
        for _ in 0..7 {
            f.processed();
        }
        f.dropped(true);
        f.dropped(false);
        let s = f.snapshot();
        assert_eq!(s.produced, 10);
        assert_eq!(s.processed, 7);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.dropped_during_downtime, 1);
        assert!((s.drop_rate() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::default();
        for ms in [1u64, 2, 3, 10, 20, 100, 200, 500] {
            h.record(Duration::from_millis(ms));
        }
        let p50 = h.quantile_approx(0.5).unwrap();
        let p99 = h.quantile_approx(0.99).unwrap();
        assert!(p50 <= p99);
        assert_eq!(h.count(), 8);
        let s = h.summary().unwrap();
        assert_eq!(s.n, 8);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::default();
        assert!(h.quantile_approx(0.5).is_none());
        assert!(h.summary().is_none());
    }

    #[test]
    fn table_markdown() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.0 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0 us");
        assert_eq!(fmt_duration(Duration::from_nanos(42)), "42 ns");
    }
}
