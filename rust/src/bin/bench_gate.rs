//! CI bench regression gate.
//!
//! ```text
//! bench_gate <baseline.json> <current.json> [tolerance]
//! ```
//!
//! Compares two `BENCH_*.json` documents (the `results_to_json` format)
//! row-by-row on mean time and exits non-zero if any row is more than
//! `tolerance` (default 0.15 = 15%) slower than the committed baseline.
//! Rows present in only one file — renamed or newly added benches — are
//! ignored, so the gate only ever fails on a genuine regression.
//!
//! A baseline flagged `"provisional": true` (a hand-seeded placeholder,
//! not numbers from a reference machine) reports regressions loudly but
//! never fails the gate — regenerate it with `cargo bench --bench
//! hot_path` on the reference machine and commit the output to arm it.

use neukonfig::bench::{baseline_is_provisional, compare_baselines};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let (baseline_path, current_path) = match (args.get(1), args.get(2)) {
        (Some(b), Some(c)) => (b.clone(), c.clone()),
        _ => {
            eprintln!("usage: bench_gate <baseline.json> <current.json> [tolerance]");
            std::process::exit(2);
        }
    };
    let tolerance: f64 = match args.get(3) {
        Some(t) => t
            .parse()
            .map_err(|e| anyhow::anyhow!("bad tolerance {t:?}: {e}"))?,
        None => 0.15,
    };

    let baseline = std::fs::read_to_string(&baseline_path)
        .map_err(|e| anyhow::anyhow!("reading {baseline_path}: {e}"))?;
    let current = std::fs::read_to_string(&current_path)
        .map_err(|e| anyhow::anyhow!("reading {current_path}: {e}"))?;

    let provisional = baseline_is_provisional(&baseline);
    let rows = compare_baselines(&baseline, &current, tolerance)?;
    if rows.is_empty() {
        println!("bench gate: no comparable rows (all renamed or first run) — pass");
        return Ok(());
    }

    let mut regressions = 0usize;
    println!(
        "bench gate: {} comparable rows, tolerance {:.0}%",
        rows.len(),
        tolerance * 100.0
    );
    for r in &rows {
        let verdict = if r.regressed {
            regressions += 1;
            "REGRESSED"
        } else if r.ratio < 1.0 {
            "improved"
        } else {
            "ok"
        };
        println!(
            "  {:<55} {:>12.6}s -> {:>12.6}s  ({:+6.1}%)  {}",
            r.name,
            r.baseline_mean,
            r.current_mean,
            (r.ratio - 1.0) * 100.0,
            verdict
        );
    }
    if regressions > 0 {
        if provisional {
            println!(
                "bench gate: {regressions} row(s) over tolerance, but the baseline is \
                 PROVISIONAL (hand-seeded placeholder, not reference-machine numbers) — \
                 reported, not failing. Regenerate with `cargo bench --bench hot_path` \
                 on the reference machine and commit BENCH_hot_path.json to arm the gate."
            );
            return Ok(());
        }
        eprintln!(
            "bench gate: {regressions} row(s) regressed more than {:.0}% vs baseline",
            tolerance * 100.0
        );
        std::process::exit(1);
    }
    if provisional {
        println!(
            "bench gate: pass (baseline still PROVISIONAL — regenerate on the \
             reference machine to make the gate authoritative)"
        );
    } else {
        println!("bench gate: pass");
    }
    Ok(())
}
