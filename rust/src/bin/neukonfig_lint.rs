//! `neukonfig_lint` — enforce the repo's concurrency/determinism
//! invariants as hard errors (see `neukonfig::lint` for the rules and
//! DESIGN.md §Concurrency invariants for the rationale).
//!
//! Usage:
//!
//! ```text
//! cargo run --bin neukonfig_lint              # lint rust/src (the tree)
//! cargo run --bin neukonfig_lint -- PATH...   # lint specific files/dirs
//! ```
//!
//! Exit status: 0 when clean, 1 when any rule fires, 2 on I/O failure.

use std::path::PathBuf;
use std::process::ExitCode;

use neukonfig::lint::{lint_tree, LintConfig, Rule};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from("rust/src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };

    let cfg = LintConfig::default();
    let mut findings = Vec::new();
    for root in &roots {
        match lint_tree(root, &cfg) {
            Ok(f) => findings.extend(f),
            Err(e) => {
                eprintln!("neukonfig_lint: cannot read {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }

    if findings.is_empty() {
        println!(
            "neukonfig_lint: clean ({} rule{} over {})",
            Rule::ALL.len(),
            if Rule::ALL.len() == 1 { "" } else { "s" },
            roots
                .iter()
                .map(|r| r.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        );
        return ExitCode::SUCCESS;
    }

    for f in &findings {
        eprintln!("error: {f}");
        eprintln!("       fix: {}", f.rule.hint());
    }
    eprintln!(
        "neukonfig_lint: {} violation{} — these invariants are hard errors \
         (waive a line with `neukonfig_lint: allow(<rule>) — reason`)",
        findings.len(),
        if findings.len() == 1 { "" } else { "s" },
    );
    ExitCode::FAILURE
}
