//! Activation-transfer codec for the edge->cloud hand-off.
//!
//! The paper's transfer term `T_t = latency + bytes/bandwidth` (Equation 1)
//! dominates end-to-end latency at the testbed's 5-20 Mbps uplinks, and the
//! bytes crossing the cut are the one factor the system controls after the
//! split is chosen. This module encodes the intermediate activation before
//! it enters [`crate::netsim::Link`] and decodes it on the cloud side:
//!
//! * [`TransferCodec::Fp32`] — lossless baseline: the raw f32 bytes ship
//!   untouched, bitwise- and duration-identical to the pre-codec pipeline.
//! * [`TransferCodec::Fp16`] — software IEEE binary16 with round-to-nearest-
//!   even and overflow *clamped* to ±65504 (no infinities on the wire).
//!   Halves the payload; reconstruction error is bounded by
//!   `|x| * 2^-11 + 3e-8` for `|x| <= 65504`.
//! * [`TransferCodec::Int8`] — per-tensor affine quantisation
//!   (`x ~ min + q * scale`, `q` in 0..=255, scale/zero-point in f64 so
//!   extreme f32 spans cannot overflow). Quarters the payload plus a
//!   16-byte header; error is bounded by `scale / 2` plus one f32 ulp, and
//!   constant tensors round-trip exactly.
//!
//! The codec must be visible to the planner, not bolted on after it: a
//! quartered payload moves the Equation-1 optimum (see
//! [`crate::profiler::ModelProfile::optimal_split_coded`]), which is why
//! [`TransferCodec::encoded_bytes`] is the single wire-byte model shared by
//! the live pipeline, the planner, and the manifests.
//!
//! Selected via `BuildOptions.transfer_codec` / `NEUKONFIG_TRANSFER_CODEC`
//! (`fp32` | `fp16` | `int8`; unset = `fp32`).

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

/// Bytes of the Int8 side-channel header (min + scale, both f64).
pub const INT8_HEADER_BYTES: usize = 16;

/// How the intermediate activation is encoded for the uplink.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransferCodec {
    /// Raw f32 bytes — lossless, the pre-codec behaviour.
    #[default]
    Fp32,
    /// IEEE binary16, overflow clamped to +-65504.
    Fp16,
    /// Per-tensor affine 8-bit quantisation.
    Int8,
}

impl TransferCodec {
    /// Parse a codec name (the `NEUKONFIG_TRANSFER_CODEC` format). Unset,
    /// empty, or unrecognised values fall back to the lossless baseline.
    pub fn parse(raw: Option<&str>) -> TransferCodec {
        match raw.map(|s| s.trim().to_ascii_lowercase()).as_deref() {
            Some("fp16") | Some("f16") | Some("half") => TransferCodec::Fp16,
            Some("int8") | Some("i8") | Some("u8") => TransferCodec::Int8,
            _ => TransferCodec::Fp32,
        }
    }

    /// Codec selection from `NEUKONFIG_TRANSFER_CODEC`.
    pub fn from_env() -> TransferCodec {
        Self::parse(std::env::var("NEUKONFIG_TRANSFER_CODEC").ok().as_deref())
    }

    pub fn label(&self) -> &'static str {
        match self {
            TransferCodec::Fp32 => "fp32",
            TransferCodec::Fp16 => "fp16",
            TransferCodec::Int8 => "int8",
        }
    }

    /// Wire bytes for a raw f32 payload of `raw_bytes` — the single
    /// byte model shared by the pipeline, the planner, and the manifests.
    pub fn encoded_bytes(&self, raw_bytes: usize) -> usize {
        match self {
            TransferCodec::Fp32 => raw_bytes,
            TransferCodec::Fp16 => raw_bytes / 2,
            TransferCodec::Int8 => raw_bytes / 4 + INT8_HEADER_BYTES,
        }
    }
}

/// An encoded activation payload.
#[derive(Debug, Clone)]
pub enum EncodedPayload {
    /// Raw little-endian f32 bytes.
    Fp32(Vec<u8>),
    /// binary16 bit patterns, one per element.
    Fp16(Vec<u16>),
    /// Quantised bytes plus the per-tensor affine parameters.
    Int8 { q: Vec<u8>, min: f64, scale: f64 },
}

/// An encoded activation with enough metadata to rebuild the `Literal`.
#[derive(Debug, Clone)]
pub struct EncodedActivation {
    pub codec: TransferCodec,
    /// Array dims of the source literal (f32, row-major).
    pub dims: Vec<usize>,
    /// Size of the source literal in bytes.
    pub raw_bytes: usize,
    pub payload: EncodedPayload,
}

impl EncodedActivation {
    /// Bytes that actually cross the link.
    pub fn wire_bytes(&self) -> usize {
        match &self.payload {
            EncodedPayload::Fp32(b) => b.len(),
            EncodedPayload::Fp16(h) => h.len() * 2,
            EncodedPayload::Int8 { q, .. } => q.len() + INT8_HEADER_BYTES,
        }
    }

    /// `raw / wire` — 1.0 for the lossless baseline, ~2 for fp16, ~4 for
    /// int8.
    pub fn compression_ratio(&self) -> f64 {
        let wire = self.wire_bytes();
        if wire == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / wire as f64
        }
    }
}

// --- binary16 bit conversion (no `half` crate offline) ------------------

/// f32 -> binary16 bits: round-to-nearest-even, overflow clamped to the
/// largest finite f16 (±65504) so no infinities are manufactured on the
/// wire. NaN stays NaN; inputs below ~2^-25 round to (signed) zero.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let mant32 = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf / NaN: clamp infinities like any overflow; keep NaN quiet.
        return if mant32 != 0 { sign | 0x7e00 } else { sign | 0x7bff };
    }
    let e = exp32 - 127 + 15; // biased binary16 exponent
    if e >= 0x1f {
        return sign | 0x7bff; // overflow: clamp to 65504
    }
    if e <= 0 {
        // Subnormal (or zero) in f16: value = h * 2^-24 with h a 10-bit
        // field. h = (mant | implicit-one) >> (14 - e), RNE on the
        // shifted-out bits; a carry into h = 0x400 lands exactly on the
        // smallest normal (2^-14), which the bit layout encodes for free.
        if e < -10 {
            return sign; // below half the smallest subnormal
        }
        let m = mant32 | 0x0080_0000;
        let shift = (14 - e) as u32;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = m >> shift;
        if rem > halfway || (rem == halfway && (h & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    // Normal range: round the 23-bit mantissa to 10 bits (RNE). A mantissa
    // carry propagates into the exponent arithmetically; if it carries past
    // the largest finite exponent, clamp.
    let mut h = ((e as u32) << 10) | (mant32 >> 13);
    let rem = mant32 & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
        h += 1;
    }
    if (h >> 10) >= 0x1f {
        return sign | 0x7bff;
    }
    sign | h as u16
}

/// binary16 bits -> f32. Exact: every finite f16 is representable in f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let e = ((h >> 10) & 0x1f) as i32;
    let mant = (h & 0x3ff) as u32;
    if e == 0x1f {
        return if mant != 0 {
            f32::NAN
        } else {
            sign * f32::INFINITY
        };
    }
    if e == 0 {
        // Subnormal: mant * 2^-24 (0x3380_0000 is exactly 2^-24).
        return sign * mant as f32 * f32::from_bits(0x3380_0000);
    }
    let bits = (((e - 15 + 127) as u32) << 23) | (mant << 13);
    sign * f32::from_bits(bits)
}

// --- slice-level encode / decode -----------------------------------------

/// Encode a host f32 slice under `codec`.
pub fn encode_f32s(codec: TransferCodec, values: &[f32]) -> EncodedPayload {
    match codec {
        TransferCodec::Fp32 => {
            let mut bytes = Vec::with_capacity(values.len() * 4);
            for v in values {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            EncodedPayload::Fp32(bytes)
        }
        TransferCodec::Fp16 => {
            EncodedPayload::Fp16(values.iter().map(|&v| f32_to_f16_bits(v)).collect())
        }
        TransferCodec::Int8 => {
            // Range scan and quantisation both in f64: an f32 span like
            // [-3e38, 3e38] overflows f32 arithmetic but is tiny for f64.
            let mut min = f64::INFINITY;
            let mut max = f64::NEG_INFINITY;
            for &v in values {
                let v = v as f64;
                if v < min {
                    min = v;
                }
                if v > max {
                    max = v;
                }
            }
            if !(min.is_finite() && max.is_finite()) {
                // Empty or all-non-finite tensor: degenerate parameters.
                min = 0.0;
                max = 0.0;
            }
            let span = max - min;
            let scale = if span > 0.0 { span / 255.0 } else { 1.0 };
            let q = values
                .iter()
                .map(|&v| ((v as f64 - min) / scale).round().clamp(0.0, 255.0) as u8)
                .collect();
            EncodedPayload::Int8 { q, min, scale }
        }
    }
}

/// Decode a payload back to host f32s.
pub fn decode_to_f32s(payload: &EncodedPayload) -> Vec<f32> {
    match payload {
        EncodedPayload::Fp32(bytes) => bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
        EncodedPayload::Fp16(halves) => halves.iter().map(|&h| f16_bits_to_f32(h)).collect(),
        EncodedPayload::Int8 { q, min, scale } => q
            .iter()
            .map(|&b| (min + b as f64 * scale) as f32)
            .collect(),
    }
}

// --- Literal-level encode / decode ---------------------------------------

fn f32_dims(l: &Literal) -> Result<Vec<usize>> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("codec: non-array literal: {e:?}"))?;
    Ok(shape.dims().iter().map(|&d| d as usize).collect())
}

/// Encode an f32 `Literal` for the wire.
pub fn encode_literal(codec: TransferCodec, l: &Literal) -> Result<EncodedActivation> {
    let dims = f32_dims(l)?;
    let raw = l.raw_buf();
    let expected: usize = dims.iter().product::<usize>() * 4;
    anyhow::ensure!(
        raw.len() == expected,
        "codec: {} raw bytes but f32 shape {dims:?} needs {expected}",
        raw.len()
    );
    let payload = match codec {
        // Fp32 keeps the raw bytes verbatim — no float parsing, so the
        // round trip is bitwise-identical by construction.
        TransferCodec::Fp32 => EncodedPayload::Fp32(raw.to_vec()),
        _ => {
            // chunks_exact + from_le_bytes: no alignment assumptions on the
            // literal's raw buffer.
            let values: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            encode_f32s(codec, &values)
        }
    };
    Ok(EncodedActivation { codec, dims, raw_bytes: raw.len(), payload })
}

/// Rebuild the f32 `Literal` the cloud chain consumes.
pub fn decode_literal(enc: &EncodedActivation) -> Result<Literal> {
    let bytes: Vec<u8> = match &enc.payload {
        EncodedPayload::Fp32(b) => b.clone(),
        other => {
            let values = decode_to_f32s(other);
            let mut bytes = Vec::with_capacity(values.len() * 4);
            for v in &values {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            bytes
        }
    };
    anyhow::ensure!(
        bytes.len() == enc.raw_bytes,
        "codec: decoded {} bytes but the source literal had {}",
        bytes.len(),
        enc.raw_bytes
    );
    Literal::create_from_shape_and_untyped_data(ElementType::F32, &enc.dims, &bytes)
        .map_err(|e| anyhow!("codec: rebuilding literal: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label() {
        assert_eq!(TransferCodec::parse(None), TransferCodec::Fp32);
        assert_eq!(TransferCodec::parse(Some("")), TransferCodec::Fp32);
        assert_eq!(TransferCodec::parse(Some("bogus")), TransferCodec::Fp32);
        assert_eq!(TransferCodec::parse(Some("fp32")), TransferCodec::Fp32);
        assert_eq!(TransferCodec::parse(Some(" FP16 ")), TransferCodec::Fp16);
        assert_eq!(TransferCodec::parse(Some("half")), TransferCodec::Fp16);
        assert_eq!(TransferCodec::parse(Some("Int8")), TransferCodec::Int8);
        assert_eq!(TransferCodec::Fp16.label(), "fp16");
        assert_eq!(TransferCodec::default(), TransferCodec::Fp32);
    }

    #[test]
    fn wire_byte_model() {
        assert_eq!(TransferCodec::Fp32.encoded_bytes(4096), 4096);
        assert_eq!(TransferCodec::Fp16.encoded_bytes(4096), 2048);
        assert_eq!(TransferCodec::Int8.encoded_bytes(4096), 1024 + 16);
        assert_eq!(TransferCodec::Int8.encoded_bytes(0), INT8_HEADER_BYTES);
    }

    #[test]
    fn f16_known_values_exact() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),
            (6.103_515_6e-5, 0x0400), // smallest normal, 2^-14
            (5.960_464_5e-8, 0x0001), // smallest subnormal, 2^-24
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "encode {x}");
            assert_eq!(f16_bits_to_f32(bits), x, "decode {bits:#06x}");
        }
    }

    #[test]
    fn f16_overflow_clamps_not_inf() {
        assert_eq!(f32_to_f16_bits(1e9), 0x7bff);
        assert_eq!(f32_to_f16_bits(f32::MAX), 0x7bff);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfbff);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7bff);
        // 65520 is the RNE midpoint to inf; we clamp instead.
        assert_eq!(f32_to_f16_bits(65520.0), 0x7bff);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_rne_ties_to_even() {
        // 1 + 2^-11 sits exactly between 1.0 (even) and 1 + 2^-10: RNE
        // keeps the even mantissa.
        let tie = 1.0 + (2.0f32).powi(-11);
        assert_eq!(f32_to_f16_bits(tie), 0x3c00);
        // 1 + 3*2^-11 ties between odd and even: rounds up to even.
        let tie_up = 1.0 + 3.0 * (2.0f32).powi(-11);
        assert_eq!(f32_to_f16_bits(tie_up), 0x3c02);
    }

    #[test]
    fn int8_constant_tensor_round_trips_exactly() {
        let xs = vec![3.7f32; 100];
        let enc = encode_f32s(TransferCodec::Int8, &xs);
        let back = decode_to_f32s(&enc);
        assert_eq!(back, xs);
        if let EncodedPayload::Int8 { q, min, scale } = enc {
            assert!(q.iter().all(|&b| b == 0));
            assert_eq!(min, 3.7f32 as f64);
            assert_eq!(scale, 1.0);
        } else {
            panic!("wrong payload variant");
        }
    }

    #[test]
    fn int8_endpoints_are_exact() {
        let xs = [-2.0f32, -1.0, 0.0, 1.5, 8.0];
        let back = decode_to_f32s(&encode_f32s(TransferCodec::Int8, &xs));
        // min and max always land on exact grid points 0 and 255.
        assert_eq!(back[0], -2.0);
        assert_eq!(back[4], 8.0);
        let scale = 10.0 / 255.0;
        for (x, y) in xs.iter().zip(&back) {
            assert!(
                (*x as f64 - *y as f64).abs() <= scale / 2.0 + 1e-9,
                "{x} -> {y}"
            );
        }
    }

    #[test]
    fn int8_extreme_span_does_not_overflow() {
        let xs = [-3.0e38f32, 3.0e38];
        let enc = encode_f32s(TransferCodec::Int8, &xs);
        if let EncodedPayload::Int8 { min, scale, .. } = &enc {
            assert!(min.is_finite() && scale.is_finite());
        }
        let back = decode_to_f32s(&enc);
        assert!(back.iter().all(|v| v.is_finite()));
        assert_eq!(back[0], -3.0e38);
        assert_eq!(back[1], 3.0e38);
    }

    #[test]
    fn fp32_slice_round_trip_is_bitwise() {
        let xs = [0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, 3.4e38, -1e-42];
        let back = decode_to_f32s(&encode_f32s(TransferCodec::Fp32, &xs));
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_tensor_is_harmless() {
        for codec in [TransferCodec::Fp32, TransferCodec::Fp16, TransferCodec::Int8] {
            let enc = encode_f32s(codec, &[]);
            assert!(decode_to_f32s(&enc).is_empty());
        }
    }
}
