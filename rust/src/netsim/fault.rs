//! Deterministic link-fault injection (DESIGN.md §Fault model).
//!
//! The paper's link only ever changes *speed*; a real uplink also loses
//! chunks, spikes in latency, and goes down outright. A [`FaultPlan`]
//! attaches a time-windowed fault schedule to a [`super::Link`]: every
//! chunk a transfer serialises consults the plan at the chunk's timeline
//! instant, so faults compose with [`super::Link::schedule_bandwidth`]
//! repricing on the same clock. Randomness (chunk loss) comes from the
//! in-tree xorshift64* PRNG seeded explicitly — the same seed and
//! schedule always fault the same chunks, which is what lets the
//! failure-injection tests assert counters exactly.
//!
//! Configuration: `NEUKONFIG_FAULT_PROFILE` holds a `;`-separated list of
//! windows, e.g. `loss:0.01@0..10;outage@5..6.5;spike:0.05@2..3`
//! (seconds on the experiment timeline; `loss` takes a probability,
//! `spike` an extra delay in seconds). `NEUKONFIG_FAULT_SEED` seeds the
//! loss draws. Unset profile means no plan — the link is then
//! byte- and duration-identical to the fault-free model.

use std::fmt;
use std::time::Duration;

use crate::util::prng::Prng;

/// One kind of injected fault, active inside a [`FaultWindow`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkFault {
    /// Each chunk serialised inside the window is lost with this
    /// probability (drawn from the plan's seeded PRNG). A lost chunk
    /// aborts the transfer attempt after charging the wasted
    /// serialisation time.
    ChunkLoss { probability: f64 },
    /// Every chunk inside the window pays `extra` on top of its
    /// serialisation time (bufferbloat / retransmission stand-in).
    LatencySpike { extra: Duration },
    /// The link is down: a chunk that starts inside the window aborts
    /// the attempt immediately, without charging that chunk.
    Outage,
}

/// A fault active on the half-open timeline interval `[from, until)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultWindow {
    pub from: Duration,
    pub until: Duration,
    pub fault: LinkFault,
}

impl FaultWindow {
    pub fn contains(&self, at: Duration) -> bool {
        self.from <= at && at < self.until
    }
}

/// A seeded, time-windowed fault schedule for one link.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    windows: Vec<FaultWindow>,
    prng: Prng,
}

impl FaultPlan {
    pub fn new(seed: u64, mut windows: Vec<FaultWindow>) -> Self {
        windows.sort_by_key(|w| w.from);
        FaultPlan { windows, prng: Prng::new(seed) }
    }

    /// Parse `NEUKONFIG_FAULT_PROFILE` syntax. Lenient like the other env
    /// knobs: malformed entries are skipped, an empty result is a plan
    /// that never faults.
    pub fn parse(profile: &str, seed: u64) -> Self {
        FaultPlan::new(seed, parse_windows(profile))
    }

    /// Build from `NEUKONFIG_FAULT_PROFILE` / `NEUKONFIG_FAULT_SEED`.
    /// `None` when no profile is set — the common, fault-free case.
    pub fn from_env() -> Option<Self> {
        let profile = std::env::var("NEUKONFIG_FAULT_PROFILE").ok()?;
        if profile.trim().is_empty() {
            return None;
        }
        let seed = std::env::var("NEUKONFIG_FAULT_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(DEFAULT_FAULT_SEED);
        Some(FaultPlan::parse(&profile, seed))
    }

    /// The fault active at timeline instant `at`, if any. Windows are
    /// consulted in start order; the first match wins, so an outage
    /// listed before a loss window shadows it where they overlap.
    pub fn fault_at(&self, at: Duration) -> Option<LinkFault> {
        self.windows.iter().find(|w| w.contains(at)).map(|w| w.fault)
    }

    /// Seeded Bernoulli draw for a [`LinkFault::ChunkLoss`] window.
    pub fn draw_loss(&mut self, probability: f64) -> bool {
        self.prng.chance(probability)
    }

    pub fn windows(&self) -> &[FaultWindow] {
        &self.windows
    }
}

pub const DEFAULT_FAULT_SEED: u64 = 0xFA17;

/// Parse the profile grammar: `kind[:param]@from..until` entries joined
/// by `;`. Invalid entries (unknown kind, unparsable numbers, negative
/// times, empty windows) are dropped, matching the lenient env-knob
/// convention elsewhere in the tree.
fn parse_windows(profile: &str) -> Vec<FaultWindow> {
    profile.split(';').filter_map(parse_window).collect()
}

fn parse_window(entry: &str) -> Option<FaultWindow> {
    let entry = entry.trim();
    let (head, span) = entry.split_once('@')?;
    let (from_s, until_s) = span.split_once("..")?;
    let from = from_s.trim().parse::<f64>().ok().filter(|v| *v >= 0.0)?;
    let until = until_s.trim().parse::<f64>().ok().filter(|v| *v > from)?;
    let (kind, param) = match head.split_once(':') {
        Some((k, p)) => (k.trim(), Some(p.trim())),
        None => (head.trim(), None),
    };
    let fault = match kind {
        "loss" => LinkFault::ChunkLoss {
            probability: param?.parse::<f64>().ok().filter(|p| (0.0..=1.0).contains(p))?,
        },
        "spike" => LinkFault::LatencySpike {
            extra: Duration::from_secs_f64(
                param?.parse::<f64>().ok().filter(|v| *v >= 0.0)?,
            ),
        },
        "outage" => LinkFault::Outage,
        _ => return None,
    };
    Some(FaultWindow {
        from: Duration::from_secs_f64(from),
        until: Duration::from_secs_f64(until),
        fault,
    })
}

/// Which fault class ended a transfer attempt — carried by the errors so
/// retry/exhaustion accounting can tell an outage from chunk loss.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    ChunkLoss,
    LatencySpike,
    Outage,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::ChunkLoss => write!(f, "chunk loss"),
            FaultKind::LatencySpike => write!(f, "latency spike"),
            FaultKind::Outage => write!(f, "outage"),
        }
    }
}

/// One transfer *attempt* aborted by an injected fault. `elapsed` is the
/// link time the failed attempt still consumed (queueing + latency +
/// serialisation up to and including the lost chunk).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferFault {
    pub kind: FaultKind,
    /// Index of the chunk the attempt died on.
    pub chunk: usize,
    pub elapsed: Duration,
}

impl fmt::Display for TransferFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "link fault ({}) at chunk {} after {:?}",
            self.kind, self.chunk, self.elapsed
        )
    }
}

impl std::error::Error for TransferFault {}

/// A whole transfer abandoned: every retry allowed by the
/// [`RetryPolicy`] faulted, or the retry deadline passed. Runners
/// downcast to this to drop the frame instead of failing the stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferAborted {
    /// Attempts actually made (including the first).
    pub attempts: u32,
    pub last_fault: FaultKind,
    pub deadline_exceeded: bool,
    /// Link time consumed across all failed attempts.
    pub elapsed: Duration,
}

impl fmt::Display for TransferAborted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.deadline_exceeded {
            write!(
                f,
                "transfer abandoned: deadline passed after {} attempt(s) ({}), {:?} on the link",
                self.attempts, self.last_fault, self.elapsed
            )
        } else {
            write!(
                f,
                "transfer abandoned: {} attempt(s) exhausted ({}), {:?} on the link",
                self.attempts, self.last_fault, self.elapsed
            )
        }
    }
}

impl std::error::Error for TransferAborted {}

/// Retry discipline for a faultable transfer: up to `max_attempts`
/// tries, exponential backoff between them, and an optional overall
/// deadline after which the frame is dropped (the Fig. 14/15 frame-drop
/// regime) instead of wedging the stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    pub base_backoff: Duration,
    pub deadline: Option<Duration>,
}

pub const DEFAULT_MAX_ATTEMPTS: u32 = 3;
pub const DEFAULT_BASE_BACKOFF: Duration = Duration::from_millis(25);

impl Default for RetryPolicy {
    /// Reads the `NEUKONFIG_RETRY_*` env knobs, like
    /// `BuildOptions::default` does for the codec.
    fn default() -> Self {
        RetryPolicy::from_env()
    }
}

impl RetryPolicy {
    /// The hard-coded defaults, ignoring the environment.
    pub fn base() -> Self {
        RetryPolicy {
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            base_backoff: DEFAULT_BASE_BACKOFF,
            deadline: None,
        }
    }

    /// `NEUKONFIG_RETRY_MAX_ATTEMPTS` / `NEUKONFIG_RETRY_BACKOFF_MS` /
    /// `NEUKONFIG_RETRY_DEADLINE_MS`, each falling back leniently.
    pub fn from_env() -> Self {
        let base = RetryPolicy::base();
        RetryPolicy {
            max_attempts: std::env::var("NEUKONFIG_RETRY_MAX_ATTEMPTS")
                .ok()
                .and_then(|s| s.trim().parse::<u32>().ok())
                .filter(|n| *n > 0)
                .unwrap_or(base.max_attempts),
            base_backoff: std::env::var("NEUKONFIG_RETRY_BACKOFF_MS")
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
                .map(Duration::from_millis)
                .unwrap_or(base.base_backoff),
            deadline: std::env::var("NEUKONFIG_RETRY_DEADLINE_MS")
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
                .filter(|ms| *ms > 0)
                .map(Duration::from_millis),
        }
    }

    /// Backoff slept before the given 1-based attempt: nothing before
    /// the first, then `base * 2^(attempt - 2)` (exponent capped so a
    /// huge attempt count cannot overflow the shift).
    pub fn backoff_before(&self, attempt: u32) -> Duration {
        if attempt <= 1 {
            return Duration::ZERO;
        }
        self.base_backoff * (1u32 << (attempt - 2).min(16))
    }
}

/// Per-link fault counters, snapshot via [`super::Link::fault_counters`].
/// These count *link-level* events; retry/drop accounting lives in
/// `metrics::FaultStats` at the pipeline layer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkFaultCounters {
    /// Chunks lost to [`LinkFault::ChunkLoss`] draws.
    pub chunks_lost: u64,
    /// Chunks that paid a [`LinkFault::LatencySpike`] surcharge.
    pub latency_spike_chunks: u64,
    /// Transfer attempts aborted by an [`LinkFault::Outage`] window.
    pub outage_aborts: u64,
    /// Transfer attempts that ended in any fault.
    pub failed_transfers: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(s: f64) -> Duration {
        Duration::from_secs_f64(s)
    }

    #[test]
    fn parses_full_profile() {
        let ws = parse_windows("loss:0.01@0..10;outage@5..6.5;spike:0.05@2..3");
        assert_eq!(ws.len(), 3);
        assert_eq!(
            ws[0],
            FaultWindow {
                from: secs(0.0),
                until: secs(10.0),
                fault: LinkFault::ChunkLoss { probability: 0.01 },
            }
        );
        assert_eq!(
            ws[1],
            FaultWindow { from: secs(5.0), until: secs(6.5), fault: LinkFault::Outage }
        );
        assert_eq!(
            ws[2],
            FaultWindow {
                from: secs(2.0),
                until: secs(3.0),
                fault: LinkFault::LatencySpike { extra: secs(0.05) },
            }
        );
    }

    #[test]
    fn skips_malformed_entries() {
        assert!(parse_windows("").is_empty());
        assert!(parse_windows("loss@0..1").is_empty()); // loss needs a probability
        assert!(parse_windows("loss:1.5@0..1").is_empty()); // p > 1
        assert!(parse_windows("loss:0.1@-1..1").is_empty()); // negative time
        assert!(parse_windows("loss:0.1@2..1").is_empty()); // empty window
        assert!(parse_windows("flood:0.1@0..1").is_empty()); // unknown kind
        assert!(parse_windows("outage@nope..1").is_empty());
        // One bad entry does not sink its neighbours.
        let ws = parse_windows("garbage;outage@1..2; loss:0.5@0..4 ");
        assert_eq!(ws.len(), 2);
    }

    #[test]
    fn first_window_in_start_order_wins() {
        let plan = FaultPlan::parse("loss:0.5@0..10;outage@2..4", 1);
        assert_eq!(
            plan.fault_at(secs(3.0)),
            Some(LinkFault::ChunkLoss { probability: 0.5 }),
            "windows sort by start; earlier-starting window shadows"
        );
        assert_eq!(plan.fault_at(secs(20.0)), None);
        // Half-open: the instant a window ends, it no longer applies.
        let plan = FaultPlan::parse("outage@1..2", 1);
        assert_eq!(plan.fault_at(secs(1.0)), Some(LinkFault::Outage));
        assert_eq!(plan.fault_at(secs(2.0)), None);
    }

    #[test]
    fn loss_draws_are_seed_deterministic() {
        let mut a = FaultPlan::parse("loss:0.3@0..1", 42);
        let mut b = FaultPlan::parse("loss:0.3@0..1", 42);
        let draws_a: Vec<bool> = (0..64).map(|_| a.draw_loss(0.3)).collect();
        let draws_b: Vec<bool> = (0..64).map(|_| b.draw_loss(0.3)).collect();
        assert_eq!(draws_a, draws_b);
        assert!(draws_a.iter().any(|d| *d));
        assert!(draws_a.iter().any(|d| !*d));
    }

    #[test]
    fn backoff_doubles_from_second_retry() {
        let p = RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_millis(10),
            deadline: None,
        };
        assert_eq!(p.backoff_before(1), Duration::ZERO);
        assert_eq!(p.backoff_before(2), Duration::from_millis(10));
        assert_eq!(p.backoff_before(3), Duration::from_millis(20));
        assert_eq!(p.backoff_before(4), Duration::from_millis(40));
        // Exponent caps instead of overflowing.
        assert_eq!(p.backoff_before(100), Duration::from_millis(10) * (1 << 16));
    }

    #[test]
    fn policy_base_defaults() {
        let p = RetryPolicy::base();
        assert_eq!(p.max_attempts, DEFAULT_MAX_ATTEMPTS);
        assert_eq!(p.base_backoff, DEFAULT_BASE_BACKOFF);
        assert_eq!(p.deadline, None);
    }

    #[test]
    fn errors_display_their_cause() {
        let f = TransferFault {
            kind: FaultKind::Outage,
            chunk: 3,
            elapsed: Duration::from_millis(7),
        };
        assert!(f.to_string().contains("outage"));
        let a = TransferAborted {
            attempts: 3,
            last_fault: FaultKind::ChunkLoss,
            deadline_exceeded: false,
            elapsed: Duration::from_millis(9),
        };
        assert!(a.to_string().contains("3 attempt(s) exhausted"));
        let d = TransferAborted { deadline_exceeded: true, ..a };
        assert!(d.to_string().contains("deadline"));
    }
}
