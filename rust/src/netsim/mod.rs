//! Network emulation — the Linux `tc` analogue (DESIGN.md §Substitutions).
//!
//! The paper shapes the edge->cloud uplink with `tc` to 20 Mbps / 5 Mbps at
//! 20 ms latency. [`Link`] reproduces that: a transfer of `b` bytes costs
//! `latency + b*8 / bandwidth`, transfers are serialised FIFO (a single
//! uplink), and the bandwidth can change at runtime — which is exactly the
//! event that triggers DNN repartitioning. [`Schedule`] replays a bandwidth
//! trace against the experiment clock.

use std::sync::Mutex;
use std::time::Duration;

use crate::clock::Clock;

/// A point-to-point shaped link (edge -> cloud uplink).
pub struct Link {
    state: Mutex<LinkState>,
    clock: Clock,
}

#[derive(Debug, Clone)]
struct LinkState {
    bandwidth_mbps: f64,
    latency: Duration,
    /// Timeline instant at which the uplink becomes free (FIFO contention).
    busy_until: Duration,
    bytes_sent: u64,
    transfers: u64,
}

impl Link {
    pub fn new(clock: Clock, bandwidth_mbps: f64, latency: Duration) -> Self {
        assert!(bandwidth_mbps > 0.0, "bandwidth must be positive");
        Link {
            state: Mutex::new(LinkState {
                bandwidth_mbps,
                latency,
                busy_until: Duration::ZERO,
                bytes_sent: 0,
                transfers: 0,
            }),
            clock,
        }
    }

    /// Pure transfer-time model (Equation 1's T_t term): latency + payload
    /// serialisation at the current bandwidth. No side effects.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        let s = self.state.lock().unwrap();
        transfer_time(bytes, s.bandwidth_mbps, s.latency)
    }

    /// Perform a transfer on the experiment timeline: waits for the uplink
    /// to be free (FIFO), then for the serialisation + latency. Returns the
    /// total time this transfer experienced (queueing included).
    pub fn transfer(&self, bytes: usize) -> Duration {
        let (wait, cost) = {
            let mut s = self.state.lock().unwrap();
            let now = self.clock.now();
            let start = s.busy_until.max(now);
            let cost = transfer_time(bytes, s.bandwidth_mbps, s.latency);
            s.busy_until = start + cost;
            s.bytes_sent += bytes as u64;
            s.transfers += 1;
            (start - now, cost)
        };
        self.clock.sleep(wait + cost);
        wait + cost
    }

    /// Change the shaped bandwidth (the `tc` rate update that triggers
    /// repartitioning).
    pub fn set_bandwidth(&self, mbps: f64) {
        assert!(mbps > 0.0);
        self.state.lock().unwrap().bandwidth_mbps = mbps;
    }

    pub fn bandwidth_mbps(&self) -> f64 {
        self.state.lock().unwrap().bandwidth_mbps
    }

    pub fn latency(&self) -> Duration {
        self.state.lock().unwrap().latency
    }

    pub fn bytes_sent(&self) -> u64 {
        self.state.lock().unwrap().bytes_sent
    }

    pub fn transfers(&self) -> u64 {
        self.state.lock().unwrap().transfers
    }
}

/// latency + bytes*8/bandwidth — shared by the live link and the analytic
/// planner (both must agree or the planner would mispredict splits).
pub fn transfer_time(bytes: usize, bandwidth_mbps: f64, latency: Duration) -> Duration {
    let serialisation = (bytes as f64 * 8.0) / (bandwidth_mbps * 1e6);
    latency + Duration::from_secs_f64(serialisation)
}

/// A timed bandwidth trace: `(at, mbps)` events applied in order.
#[derive(Debug, Clone)]
pub struct Schedule {
    events: Vec<(Duration, f64)>,
    next: usize,
}

impl Schedule {
    pub fn new(mut events: Vec<(Duration, f64)>) -> Self {
        events.sort_by_key(|e| e.0);
        Schedule { events, next: 0 }
    }

    /// The paper's experiment trace: toggle 20 <-> 5 Mbps every `period`.
    pub fn toggle(high: f64, low: f64, period: Duration, cycles: usize) -> Self {
        let mut ev = Vec::new();
        for i in 1..=cycles {
            ev.push((period * i as u32, if i % 2 == 1 { low } else { high }));
        }
        Schedule::new(ev)
    }

    /// Pop all events due at or before `now`; returns the latest one.
    pub fn poll(&mut self, now: Duration) -> Option<f64> {
        let mut last = None;
        while self.next < self.events.len() && self.events[self.next].0 <= now {
            last = Some(self.events[self.next].1);
            self.next += 1;
        }
        last
    }

    pub fn peek_next(&self) -> Option<(Duration, f64)> {
        self.events.get(self.next).copied()
    }

    pub fn is_done(&self) -> bool {
        self.next >= self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_link(mbps: f64) -> Link {
        Link::new(Clock::simulated(), mbps, Duration::from_millis(20))
    }

    #[test]
    fn transfer_time_model() {
        // 20 Mbps, 1 MB payload: 20ms + 8e6/20e6 s = 20ms + 400ms.
        let t = transfer_time(1_000_000, 20.0, Duration::from_millis(20));
        assert!((t.as_secs_f64() - 0.42).abs() < 1e-9);
    }

    #[test]
    fn slower_link_is_slower() {
        let l = sim_link(20.0);
        let fast = l.transfer_time(500_000);
        l.set_bandwidth(5.0);
        let slow = l.transfer_time(500_000);
        assert!(slow > fast * 3); // 4x serialisation, same latency
    }

    #[test]
    fn transfer_advances_clock() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 20.0, Duration::from_millis(20));
        let t0 = clock.now();
        let d = l.transfer(1_000_000);
        assert!(clock.now() - t0 >= d);
        assert_eq!(l.bytes_sent(), 1_000_000);
        assert_eq!(l.transfers(), 1);
    }

    #[test]
    fn fifo_contention_accumulates() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        // 1 MB at 8 Mbps = 1 s each; three sequential transfers queue.
        l.transfer(1_000_000);
        l.transfer(1_000_000);
        l.transfer(1_000_000);
        assert!(clock.now() >= Duration::from_secs(3));
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let l = sim_link(20.0);
        assert_eq!(l.transfer_time(0), Duration::from_millis(20));
    }

    #[test]
    fn schedule_polls_in_order() {
        let mut s = Schedule::new(vec![
            (Duration::from_secs(2), 5.0),
            (Duration::from_secs(1), 10.0),
        ]);
        assert_eq!(s.poll(Duration::from_millis(500)), None);
        assert_eq!(s.poll(Duration::from_secs(1)), Some(10.0));
        assert_eq!(s.poll(Duration::from_secs(5)), Some(5.0));
        assert!(s.is_done());
    }

    #[test]
    fn schedule_poll_skips_to_latest() {
        let mut s = Schedule::new(vec![
            (Duration::from_secs(1), 10.0),
            (Duration::from_secs(2), 5.0),
        ]);
        // Both events due: the latest wins.
        assert_eq!(s.poll(Duration::from_secs(3)), Some(5.0));
    }

    #[test]
    fn toggle_alternates() {
        let s = Schedule::toggle(20.0, 5.0, Duration::from_secs(10), 4);
        let bws: Vec<f64> = s.events.iter().map(|e| e.1).collect();
        assert_eq!(bws, vec![5.0, 20.0, 5.0, 20.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bandwidth() {
        sim_link(0.0);
    }
}
