//! Network emulation — the Linux `tc` analogue (DESIGN.md §Substitutions).
//!
//! The paper shapes the edge->cloud uplink with `tc` to 20 Mbps / 5 Mbps at
//! 20 ms latency. [`Link`] reproduces that: a transfer of `b` bytes costs
//! `latency + b*8 / bandwidth`, transfers are serialised FIFO (a single
//! uplink), and the bandwidth can change at runtime — which is exactly the
//! event that triggers DNN repartitioning. [`Schedule`] replays a bandwidth
//! trace against the experiment clock.
//!
//! Payloads move in bounded chunks ([`Link::transfer_chunked`];
//! `NEUKONFIG_CHUNK_BYTES`, default 64 KiB): a bandwidth change scheduled
//! with [`Link::schedule_bandwidth`] reprices the chunks still unsent when
//! it fires, instead of the whole payload being costed at submission-time
//! bandwidth. Consecutive chunks at one bandwidth are costed as a single
//! segment with the same arithmetic as [`transfer_time`], so a transfer
//! that sees no rate change is *bitwise-identical* in cost to the
//! unchunked model.
//!
//! Links can also *fail*: [`Link::install_fault_plan`] attaches a
//! deterministic, seeded [`FaultPlan`] (chunk loss, latency spikes,
//! outages — see [`fault`]) and [`Link::try_transfer_chunked`] then
//! reports per-attempt faults instead of always succeeding. With no
//! plan installed every code path below is unchanged, bit for bit.

use std::sync::Mutex;
use std::time::Duration;

use crate::clock::Clock;
use crate::util::sync::lock_clean;

pub mod fault;

pub use fault::{
    FaultKind, FaultPlan, FaultWindow, LinkFault, LinkFaultCounters, RetryPolicy,
    TransferAborted, TransferFault,
};

/// A point-to-point shaped link (edge -> cloud uplink).
pub struct Link {
    state: Mutex<LinkState>,
    clock: Clock,
}

#[derive(Debug, Clone)]
struct LinkState {
    bandwidth_mbps: f64,
    latency: Duration,
    /// Timeline instant at which the uplink becomes free (FIFO contention).
    busy_until: Duration,
    bytes_sent: u64,
    transfers: u64,
    chunks: u64,
    /// Scheduled `(at, mbps)` bandwidth events, time-ordered. Applied when
    /// the timeline reaches them: at chunk boundaries inside a transfer,
    /// and on any state read that knows the current time.
    pending: Vec<(Duration, f64)>,
    /// Injected fault schedule; `None` (the default) means the link is
    /// the original always-succeeds model.
    fault: Option<FaultPlan>,
    faults: LinkFaultCounters,
}

impl LinkState {
    /// Apply every scheduled bandwidth event due at or before `at`.
    fn apply_pending(&mut self, at: Duration) {
        let due = self.pending.iter().take_while(|e| e.0 <= at).count();
        for (_, mbps) in self.pending.drain(..due) {
            self.bandwidth_mbps = mbps;
        }
    }
}

/// Serialisation seconds for `bytes` at `mbps` — the exact expression
/// [`transfer_time`] uses, shared so segment costing stays bit-identical.
fn seg_secs(bytes: usize, mbps: f64) -> f64 {
    (bytes as f64 * 8.0) / (mbps * 1e6)
}

impl Link {
    pub fn new(clock: Clock, bandwidth_mbps: f64, latency: Duration) -> Self {
        assert!(bandwidth_mbps > 0.0, "bandwidth must be positive");
        Link {
            state: Mutex::new(LinkState {
                bandwidth_mbps,
                latency,
                busy_until: Duration::ZERO,
                bytes_sent: 0,
                transfers: 0,
                chunks: 0,
                pending: Vec::new(),
                fault: None,
                faults: LinkFaultCounters::default(),
            }),
            clock,
        }
    }

    /// Pure transfer-time model (Equation 1's T_t term): latency + payload
    /// serialisation at the current bandwidth. Applies any scheduled
    /// bandwidth events that are already due; no other side effects.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        let mut s = lock_clean(&self.state);
        s.apply_pending(self.clock.now());
        transfer_time(bytes, s.bandwidth_mbps, s.latency)
    }

    /// Perform a transfer on the experiment timeline: waits for the uplink
    /// to be free (FIFO), then for the serialisation + latency. Returns the
    /// total time this transfer experienced (queueing included). Ships in
    /// chunks of [`default_chunk_bytes`].
    pub fn transfer(&self, bytes: usize) -> Duration {
        self.transfer_chunked(bytes, default_chunk_bytes())
    }

    /// [`Self::transfer`] with an explicit chunk size. The payload
    /// serialises chunk by chunk; a bandwidth event scheduled with
    /// [`Self::schedule_bandwidth`] reprices every chunk that starts at or
    /// after the event fires (today's rate for today's bytes — the
    /// stale-bandwidth fix). Chunks between two events collapse into one
    /// costing segment using [`transfer_time`]'s arithmetic, so with a
    /// constant bandwidth the cost is bit-identical to the unchunked model.
    pub fn transfer_chunked(&self, bytes: usize, chunk_bytes: usize) -> Duration {
        self.try_transfer_chunked(bytes, chunk_bytes).unwrap_or_else(|f| {
            panic!(
                "injected link fault with no retry handling: {f}; \
                 use try_transfer_chunked behind a RetryPolicy"
            )
        })
    }

    /// [`Self::transfer_chunked`] that can fail. Each chunk consults the
    /// installed [`FaultPlan`] at the timeline instant it starts
    /// serialising — the same instant bandwidth events are applied, so
    /// faults and repricing compose on one clock. A fault ends the
    /// *attempt*: the time already burnt (queueing, latency, chunks
    /// serialised so far — including a lost chunk's serialisation, but
    /// not an outage-aborted chunk) still occupies the link and advances
    /// the clock, and the error reports it as `elapsed`. With no plan
    /// installed the cost arithmetic is bit-identical to
    /// [`Self::transfer_chunked`]'s historical behaviour.
    pub fn try_transfer_chunked(
        &self,
        bytes: usize,
        chunk_bytes: usize,
    ) -> Result<Duration, TransferFault> {
        let chunk = chunk_bytes.max(1);
        let (wait, cost, faulted) = {
            let mut s = lock_clean(&self.state);
            let now = self.clock.now();
            let start = s.busy_until.max(now);
            // Serialisation begins once the propagation latency has passed.
            let ser_start = start + s.latency;
            s.apply_pending(ser_start);
            let n_chunks = if bytes == 0 { 0 } else { bytes.div_ceil(chunk) };
            let mut done_secs = 0.0f64; // serialisation of closed segments
            let mut fault_secs = 0.0f64; // latency-spike surcharges
            let mut seg_bytes = 0usize; // bytes in the open segment
            let mut seg_bw = s.bandwidth_mbps;
            let mut sent = 0usize;
            let mut chunks_tried = 0u64;
            let mut faulted: Option<TransferFault> = None;
            for i in 0..n_chunks {
                // Instant this chunk starts serialising; fire any events
                // due by then and close the segment if the rate moved.
                let at = ser_start
                    + Duration::from_secs_f64(
                        done_secs + fault_secs + seg_secs(seg_bytes, seg_bw),
                    );
                s.apply_pending(at);
                if s.bandwidth_mbps != seg_bw {
                    done_secs += seg_secs(seg_bytes, seg_bw);
                    seg_bytes = 0;
                    seg_bw = s.bandwidth_mbps;
                }
                match s.fault.as_ref().and_then(|p| p.fault_at(at)) {
                    Some(LinkFault::Outage) => {
                        s.faults.outage_aborts += 1;
                        faulted = Some(TransferFault {
                            kind: FaultKind::Outage,
                            chunk: i,
                            elapsed: Duration::ZERO, // filled below
                        });
                        break;
                    }
                    Some(LinkFault::ChunkLoss { probability }) => {
                        let lost = s
                            .fault
                            .as_mut()
                            .map(|p| p.draw_loss(probability))
                            .unwrap_or(false);
                        if lost {
                            // The lost chunk's serialisation is burnt
                            // wire time: charge it, then abort.
                            let this = chunk.min(bytes - sent);
                            seg_bytes += this;
                            sent += this;
                            chunks_tried += 1;
                            s.faults.chunks_lost += 1;
                            faulted = Some(TransferFault {
                                kind: FaultKind::ChunkLoss,
                                chunk: i,
                                elapsed: Duration::ZERO,
                            });
                            break;
                        }
                    }
                    Some(LinkFault::LatencySpike { extra }) => {
                        fault_secs += extra.as_secs_f64();
                        s.faults.latency_spike_chunks += 1;
                    }
                    None => {}
                }
                let this = chunk.min(bytes - sent);
                seg_bytes += this;
                sent += this;
                chunks_tried += 1;
            }
            done_secs += seg_secs(seg_bytes, seg_bw);
            let cost = s.latency + Duration::from_secs_f64(done_secs + fault_secs);
            s.busy_until = start + cost;
            s.bytes_sent += sent as u64;
            s.transfers += 1;
            s.chunks += chunks_tried;
            if faulted.is_some() {
                s.faults.failed_transfers += 1;
            }
            (start - now, cost, faulted)
        };
        self.clock.sleep(wait + cost);
        match faulted {
            Some(mut f) => {
                f.elapsed = wait + cost;
                Err(f)
            }
            None => Ok(wait + cost),
        }
    }

    /// Attach a fault schedule; subsequent transfers consult it chunk by
    /// chunk. Replaces any previous plan (and its PRNG position).
    pub fn install_fault_plan(&self, plan: FaultPlan) {
        lock_clean(&self.state).fault = Some(plan);
    }

    /// Remove the fault schedule, restoring the always-succeeds link.
    pub fn clear_fault_plan(&self) {
        lock_clean(&self.state).fault = None;
    }

    pub fn has_fault_plan(&self) -> bool {
        lock_clean(&self.state).fault.is_some()
    }

    /// Link-level fault counters (chunks lost, spiked, aborted attempts).
    pub fn fault_counters(&self) -> LinkFaultCounters {
        lock_clean(&self.state).faults
    }

    /// Change the shaped bandwidth immediately (the `tc` rate update that
    /// triggers repartitioning). Transfers already costed keep their price;
    /// use [`Self::schedule_bandwidth`] to reprice a transfer mid-flight on
    /// the simulated timeline.
    pub fn set_bandwidth(&self, mbps: f64) {
        assert!(mbps > 0.0);
        lock_clean(&self.state).bandwidth_mbps = mbps;
    }

    /// Schedule a bandwidth change at timeline instant `at`. Chunked
    /// transfers whose chunks start at or after `at` pay the new rate —
    /// deterministic mid-transfer repricing even on a simulated clock,
    /// where a whole transfer is costed inside one lock.
    pub fn schedule_bandwidth(&self, at: Duration, mbps: f64) {
        assert!(mbps > 0.0);
        let mut s = lock_clean(&self.state);
        s.pending.push((at, mbps));
        s.pending.sort_by_key(|e| e.0);
    }

    pub fn bandwidth_mbps(&self) -> f64 {
        let mut s = lock_clean(&self.state);
        s.apply_pending(self.clock.now());
        s.bandwidth_mbps
    }

    pub fn latency(&self) -> Duration {
        lock_clean(&self.state).latency
    }

    pub fn bytes_sent(&self) -> u64 {
        lock_clean(&self.state).bytes_sent
    }

    pub fn transfers(&self) -> u64 {
        lock_clean(&self.state).transfers
    }

    /// Total chunks shipped across all transfers.
    pub fn chunks(&self) -> u64 {
        lock_clean(&self.state).chunks
    }
}

/// Default transfer chunk size: `NEUKONFIG_CHUNK_BYTES`, falling back to
/// 64 KiB (unset, unparsable, or <= 0 all mean the default).
pub fn default_chunk_bytes() -> usize {
    parse_chunk_bytes(std::env::var("NEUKONFIG_CHUNK_BYTES").ok().as_deref())
}

pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

fn parse_chunk_bytes(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|b| *b > 0)
        .unwrap_or(DEFAULT_CHUNK_BYTES)
}

/// latency + bytes*8/bandwidth — shared by the live link and the analytic
/// planner (both must agree or the planner would mispredict splits).
pub fn transfer_time(bytes: usize, bandwidth_mbps: f64, latency: Duration) -> Duration {
    let serialisation = (bytes as f64 * 8.0) / (bandwidth_mbps * 1e6);
    latency + Duration::from_secs_f64(serialisation)
}

/// A timed bandwidth trace: `(at, mbps)` events applied in order.
#[derive(Debug, Clone)]
pub struct Schedule {
    events: Vec<(Duration, f64)>,
    next: usize,
}

impl Schedule {
    pub fn new(mut events: Vec<(Duration, f64)>) -> Self {
        events.sort_by_key(|e| e.0);
        Schedule { events, next: 0 }
    }

    /// The paper's experiment trace: toggle 20 <-> 5 Mbps every `period`.
    pub fn toggle(high: f64, low: f64, period: Duration, cycles: usize) -> Self {
        let mut ev = Vec::new();
        for i in 1..=cycles {
            ev.push((period * i as u32, if i % 2 == 1 { low } else { high }));
        }
        Schedule::new(ev)
    }

    /// Pop all events due at or before `now`; returns the latest one.
    pub fn poll(&mut self, now: Duration) -> Option<f64> {
        let mut last = None;
        while self.next < self.events.len() && self.events[self.next].0 <= now {
            last = Some(self.events[self.next].1);
            self.next += 1;
        }
        last
    }

    pub fn peek_next(&self) -> Option<(Duration, f64)> {
        self.events.get(self.next).copied()
    }

    pub fn is_done(&self) -> bool {
        self.next >= self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_link(mbps: f64) -> Link {
        Link::new(Clock::simulated(), mbps, Duration::from_millis(20))
    }

    #[test]
    fn transfer_time_model() {
        // 20 Mbps, 1 MB payload: 20ms + 8e6/20e6 s = 20ms + 400ms.
        let t = transfer_time(1_000_000, 20.0, Duration::from_millis(20));
        assert!((t.as_secs_f64() - 0.42).abs() < 1e-9);
    }

    #[test]
    fn slower_link_is_slower() {
        let l = sim_link(20.0);
        let fast = l.transfer_time(500_000);
        l.set_bandwidth(5.0);
        let slow = l.transfer_time(500_000);
        assert!(slow > fast * 3); // 4x serialisation, same latency
    }

    #[test]
    fn transfer_advances_clock() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 20.0, Duration::from_millis(20));
        let t0 = clock.now();
        let d = l.transfer(1_000_000);
        assert!(clock.now() - t0 >= d);
        assert_eq!(l.bytes_sent(), 1_000_000);
        assert_eq!(l.transfers(), 1);
    }

    #[test]
    fn fifo_contention_accumulates() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        // 1 MB at 8 Mbps = 1 s each; three sequential transfers queue.
        l.transfer(1_000_000);
        l.transfer(1_000_000);
        l.transfer(1_000_000);
        assert!(clock.now() >= Duration::from_secs(3));
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let l = sim_link(20.0);
        assert_eq!(l.transfer_time(0), Duration::from_millis(20));
    }

    #[test]
    fn chunked_cost_matches_unchunked_at_constant_bandwidth() {
        // Segment grouping: with no rate change, any chunk size must cost
        // bit-identically to the pre-chunking model.
        let expect = transfer_time(1_000_000, 20.0, Duration::from_millis(20));
        for chunk in [1_000_000, 65_536, 4096, 1_000_001, 1] {
            let l = sim_link(20.0);
            assert_eq!(l.transfer_chunked(1_000_000, chunk), expect, "chunk {chunk}");
        }
        let l = sim_link(20.0);
        assert_eq!(l.transfer(1_000_000), expect);
        l.transfer_chunked(1_000_000, 4096);
        assert_eq!(l.chunks(), 1_000_000usize.div_ceil(DEFAULT_CHUNK_BYTES) as u64 + 245);
        assert_eq!(l.transfers(), 2);
    }

    #[test]
    fn scheduled_rate_drop_reprices_remaining_chunks() {
        // Regression (stale-bandwidth costing): 2 MB at 8 Mbps is 2 s when
        // the whole payload is priced at submission-time bandwidth. With
        // the rate halving at t = 1 s, the chunks serialised after the
        // change must pay 4 Mbps: 16 x 64 KiB chunks (1,048,576 B) fit
        // before the event, the remaining 951,424 B cost twice as much —
        // ~2.951 s total.
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        l.schedule_bandwidth(Duration::from_secs(1), 4.0);
        let t = l.transfer_chunked(2_000_000, 65_536);
        assert!(
            t > Duration::from_secs(2),
            "transfer still priced at the stale submission bandwidth: {t:?}"
        );
        assert!(
            t >= Duration::from_secs_f64(2.9) && t <= Duration::from_secs_f64(3.0),
            "repriced cost off the chunk-granular model: {t:?}"
        );
        // The event has fired; later reads and transfers see 4 Mbps.
        assert_eq!(l.bandwidth_mbps(), 4.0);
    }

    #[test]
    fn scheduled_event_before_start_covers_whole_transfer() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        l.schedule_bandwidth(Duration::ZERO, 4.0);
        // 1 MB entirely at the new 4 Mbps rate: 2 s exactly.
        let t = l.transfer_chunked(1_000_000, 65_536);
        assert_eq!(t, transfer_time(1_000_000, 4.0, Duration::ZERO));
    }

    #[test]
    fn scheduled_rate_rise_cheapens_the_tail() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 4.0, Duration::ZERO);
        // 2 MB at 4 Mbps is 4 s flat; doubling the rate at t = 1 s leaves
        // ~1.5 MB to serialise at 8 Mbps: ~2.5 s total.
        l.schedule_bandwidth(Duration::from_secs(1), 8.0);
        let t = l.transfer_chunked(2_000_000, 65_536);
        assert!(t < Duration::from_secs(3), "tail not repriced upward: {t:?}");
        assert!(t > Duration::from_secs(2), "{t:?}");
    }

    #[test]
    fn chunk_bytes_parsing() {
        assert_eq!(parse_chunk_bytes(None), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("")), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("nope")), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("0")), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("4096")), 4096);
        assert_eq!(parse_chunk_bytes(Some(" 128 ")), 128);
    }

    #[test]
    fn schedule_polls_in_order() {
        let mut s = Schedule::new(vec![
            (Duration::from_secs(2), 5.0),
            (Duration::from_secs(1), 10.0),
        ]);
        assert_eq!(s.poll(Duration::from_millis(500)), None);
        assert_eq!(s.poll(Duration::from_secs(1)), Some(10.0));
        assert_eq!(s.poll(Duration::from_secs(5)), Some(5.0));
        assert!(s.is_done());
    }

    #[test]
    fn schedule_poll_skips_to_latest() {
        let mut s = Schedule::new(vec![
            (Duration::from_secs(1), 10.0),
            (Duration::from_secs(2), 5.0),
        ]);
        // Both events due: the latest wins.
        assert_eq!(s.poll(Duration::from_secs(3)), Some(5.0));
    }

    #[test]
    fn toggle_alternates() {
        let s = Schedule::toggle(20.0, 5.0, Duration::from_secs(10), 4);
        let bws: Vec<f64> = s.events.iter().map(|e| e.1).collect();
        assert_eq!(bws, vec![5.0, 20.0, 5.0, 20.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bandwidth() {
        sim_link(0.0);
    }

    #[test]
    fn outage_mid_transfer_aborts_and_charges_time_spent() {
        // 2 MB at 8 Mbps is 2 s clean; the link goes down at t = 0.5 s.
        // Chunks serialised before the outage succeed, the first chunk
        // that starts inside the window aborts the attempt without being
        // charged — so the failed attempt costs ~0.5 s, not 2 s.
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        l.install_fault_plan(FaultPlan::new(
            1,
            vec![FaultWindow {
                from: Duration::from_millis(500),
                until: Duration::from_secs(5),
                fault: LinkFault::Outage,
            }],
        ));
        let err = l.try_transfer_chunked(2_000_000, 65_536).unwrap_err();
        assert_eq!(err.kind, FaultKind::Outage);
        assert!(
            err.elapsed >= Duration::from_millis(450) && err.elapsed < Duration::from_millis(600),
            "aborted attempt should charge only the pre-outage chunks: {:?}",
            err.elapsed
        );
        assert_eq!(clock.now(), err.elapsed, "burnt time advances the clock");
        let c = l.fault_counters();
        assert_eq!(c.outage_aborts, 1);
        assert_eq!(c.failed_transfers, 1);
        assert_eq!(c.chunks_lost, 0);
        // Bytes that made it onto the wire before the outage are counted.
        assert!(l.bytes_sent() > 0 && l.bytes_sent() < 2_000_000);
    }

    #[test]
    fn certain_chunk_loss_kills_the_first_chunk() {
        // probability 1.0: the very first chunk is lost, after paying its
        // own serialisation (64 KiB at 8 Mbps = 65.536 ms).
        let l = sim_link(8.0);
        l.install_fault_plan(FaultPlan::parse("loss:1@0..1000", 7));
        let err = l.try_transfer_chunked(1_000_000, 65_536).unwrap_err();
        assert_eq!(err.kind, FaultKind::ChunkLoss);
        assert_eq!(err.chunk, 0);
        let expect = transfer_time(65_536, 8.0, Duration::from_millis(20));
        assert_eq!(err.elapsed, expect, "lost chunk's serialisation is charged");
        let c = l.fault_counters();
        assert_eq!(c.chunks_lost, 1);
        assert_eq!(c.failed_transfers, 1);
    }

    #[test]
    fn loss_outcomes_are_seed_deterministic() {
        let run = |seed: u64| {
            let l = sim_link(8.0);
            l.install_fault_plan(FaultPlan::parse("loss:0.2@0..1000", seed));
            let outcomes: Vec<Result<Duration, TransferFault>> =
                (0..8).map(|_| l.try_transfer_chunked(500_000, 65_536)).collect();
            (outcomes, l.fault_counters())
        };
        let (a, ca) = run(42);
        let (b, cb) = run(42);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
        assert!(ca.chunks_lost > 0, "p=0.2 over ~64 chunks should lose some");
        let (c, _) = run(43);
        assert_ne!(a, c, "different seed, different loss pattern");
    }

    #[test]
    fn latency_spike_surcharges_each_chunk() {
        // 1 MB in 16 x 64 KiB chunks, every chunk inside a 10 ms spike
        // window: cost = clean + 16 * 10 ms (within f64 rounding).
        let clean = transfer_time(1_000_000, 8.0, Duration::from_millis(20));
        let l = sim_link(8.0);
        l.install_fault_plan(FaultPlan::parse("spike:0.01@0..1000", 1));
        let t = l.try_transfer_chunked(1_000_000, 65_536).unwrap();
        let surcharge = t - clean;
        assert!(
            surcharge > Duration::from_millis(159) && surcharge < Duration::from_millis(161),
            "16 spiked chunks should add ~160 ms, got {surcharge:?}"
        );
        assert_eq!(l.fault_counters().latency_spike_chunks, 16);
        assert_eq!(l.fault_counters().failed_transfers, 0, "spikes slow, never fail");
    }

    #[test]
    fn idle_plan_is_cost_identical_to_no_plan() {
        // A plan whose windows never cover the transfer must not perturb
        // the cost by a single bit (the no-fault identity property).
        let clean = sim_link(20.0).transfer_chunked(1_000_000, 65_536);
        let l = sim_link(20.0);
        l.install_fault_plan(FaultPlan::parse("outage@100000..100001", 1));
        assert_eq!(l.try_transfer_chunked(1_000_000, 65_536).unwrap(), clean);
        assert_eq!(l.fault_counters(), LinkFaultCounters::default());
    }

    #[test]
    #[should_panic(expected = "injected link fault")]
    fn infallible_transfer_panics_on_fault() {
        let l = sim_link(8.0);
        l.install_fault_plan(FaultPlan::parse("outage@0..10", 1));
        l.transfer_chunked(1_000_000, 65_536);
    }

    #[test]
    fn clear_fault_plan_restores_the_clean_link() {
        let l = sim_link(8.0);
        l.install_fault_plan(FaultPlan::parse("outage@0..1000000", 1));
        assert!(l.has_fault_plan());
        assert!(l.try_transfer_chunked(100_000, 65_536).is_err());
        l.clear_fault_plan();
        assert!(!l.has_fault_plan());
        assert!(l.try_transfer_chunked(100_000, 65_536).is_ok());
    }
}
