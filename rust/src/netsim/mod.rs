//! Network emulation — the Linux `tc` analogue (DESIGN.md §Substitutions).
//!
//! The paper shapes the edge->cloud uplink with `tc` to 20 Mbps / 5 Mbps at
//! 20 ms latency. [`Link`] reproduces that: a transfer of `b` bytes costs
//! `latency + b*8 / bandwidth`, transfers are serialised FIFO (a single
//! uplink), and the bandwidth can change at runtime — which is exactly the
//! event that triggers DNN repartitioning. [`Schedule`] replays a bandwidth
//! trace against the experiment clock.
//!
//! Payloads move in bounded chunks ([`Link::transfer_chunked`];
//! `NEUKONFIG_CHUNK_BYTES`, default 64 KiB): a bandwidth change scheduled
//! with [`Link::schedule_bandwidth`] reprices the chunks still unsent when
//! it fires, instead of the whole payload being costed at submission-time
//! bandwidth. Consecutive chunks at one bandwidth are costed as a single
//! segment with the same arithmetic as [`transfer_time`], so a transfer
//! that sees no rate change is *bitwise-identical* in cost to the
//! unchunked model.

use std::sync::Mutex;
use std::time::Duration;

use crate::clock::Clock;

/// A point-to-point shaped link (edge -> cloud uplink).
pub struct Link {
    state: Mutex<LinkState>,
    clock: Clock,
}

#[derive(Debug, Clone)]
struct LinkState {
    bandwidth_mbps: f64,
    latency: Duration,
    /// Timeline instant at which the uplink becomes free (FIFO contention).
    busy_until: Duration,
    bytes_sent: u64,
    transfers: u64,
    chunks: u64,
    /// Scheduled `(at, mbps)` bandwidth events, time-ordered. Applied when
    /// the timeline reaches them: at chunk boundaries inside a transfer,
    /// and on any state read that knows the current time.
    pending: Vec<(Duration, f64)>,
}

impl LinkState {
    /// Apply every scheduled bandwidth event due at or before `at`.
    fn apply_pending(&mut self, at: Duration) {
        let due = self.pending.iter().take_while(|e| e.0 <= at).count();
        for (_, mbps) in self.pending.drain(..due) {
            self.bandwidth_mbps = mbps;
        }
    }
}

/// Serialisation seconds for `bytes` at `mbps` — the exact expression
/// [`transfer_time`] uses, shared so segment costing stays bit-identical.
fn seg_secs(bytes: usize, mbps: f64) -> f64 {
    (bytes as f64 * 8.0) / (mbps * 1e6)
}

impl Link {
    pub fn new(clock: Clock, bandwidth_mbps: f64, latency: Duration) -> Self {
        assert!(bandwidth_mbps > 0.0, "bandwidth must be positive");
        Link {
            state: Mutex::new(LinkState {
                bandwidth_mbps,
                latency,
                busy_until: Duration::ZERO,
                bytes_sent: 0,
                transfers: 0,
                chunks: 0,
                pending: Vec::new(),
            }),
            clock,
        }
    }

    /// Pure transfer-time model (Equation 1's T_t term): latency + payload
    /// serialisation at the current bandwidth. Applies any scheduled
    /// bandwidth events that are already due; no other side effects.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        let mut s = self.state.lock().unwrap();
        s.apply_pending(self.clock.now());
        transfer_time(bytes, s.bandwidth_mbps, s.latency)
    }

    /// Perform a transfer on the experiment timeline: waits for the uplink
    /// to be free (FIFO), then for the serialisation + latency. Returns the
    /// total time this transfer experienced (queueing included). Ships in
    /// chunks of [`default_chunk_bytes`].
    pub fn transfer(&self, bytes: usize) -> Duration {
        self.transfer_chunked(bytes, default_chunk_bytes())
    }

    /// [`Self::transfer`] with an explicit chunk size. The payload
    /// serialises chunk by chunk; a bandwidth event scheduled with
    /// [`Self::schedule_bandwidth`] reprices every chunk that starts at or
    /// after the event fires (today's rate for today's bytes — the
    /// stale-bandwidth fix). Chunks between two events collapse into one
    /// costing segment using [`transfer_time`]'s arithmetic, so with a
    /// constant bandwidth the cost is bit-identical to the unchunked model.
    pub fn transfer_chunked(&self, bytes: usize, chunk_bytes: usize) -> Duration {
        let chunk = chunk_bytes.max(1);
        let (wait, cost) = {
            let mut s = self.state.lock().unwrap();
            let now = self.clock.now();
            let start = s.busy_until.max(now);
            // Serialisation begins once the propagation latency has passed.
            let ser_start = start + s.latency;
            s.apply_pending(ser_start);
            let n_chunks = if bytes == 0 { 0 } else { bytes.div_ceil(chunk) };
            let mut done_secs = 0.0f64; // serialisation of closed segments
            let mut seg_bytes = 0usize; // bytes in the open segment
            let mut seg_bw = s.bandwidth_mbps;
            let mut sent = 0usize;
            for _ in 0..n_chunks {
                // Instant this chunk starts serialising; fire any events
                // due by then and close the segment if the rate moved.
                let at = ser_start
                    + Duration::from_secs_f64(done_secs + seg_secs(seg_bytes, seg_bw));
                s.apply_pending(at);
                if s.bandwidth_mbps != seg_bw {
                    done_secs += seg_secs(seg_bytes, seg_bw);
                    seg_bytes = 0;
                    seg_bw = s.bandwidth_mbps;
                }
                let this = chunk.min(bytes - sent);
                seg_bytes += this;
                sent += this;
            }
            done_secs += seg_secs(seg_bytes, seg_bw);
            let cost = s.latency + Duration::from_secs_f64(done_secs);
            s.busy_until = start + cost;
            s.bytes_sent += bytes as u64;
            s.transfers += 1;
            s.chunks += n_chunks as u64;
            (start - now, cost)
        };
        self.clock.sleep(wait + cost);
        wait + cost
    }

    /// Change the shaped bandwidth immediately (the `tc` rate update that
    /// triggers repartitioning). Transfers already costed keep their price;
    /// use [`Self::schedule_bandwidth`] to reprice a transfer mid-flight on
    /// the simulated timeline.
    pub fn set_bandwidth(&self, mbps: f64) {
        assert!(mbps > 0.0);
        self.state.lock().unwrap().bandwidth_mbps = mbps;
    }

    /// Schedule a bandwidth change at timeline instant `at`. Chunked
    /// transfers whose chunks start at or after `at` pay the new rate —
    /// deterministic mid-transfer repricing even on a simulated clock,
    /// where a whole transfer is costed inside one lock.
    pub fn schedule_bandwidth(&self, at: Duration, mbps: f64) {
        assert!(mbps > 0.0);
        let mut s = self.state.lock().unwrap();
        s.pending.push((at, mbps));
        s.pending.sort_by_key(|e| e.0);
    }

    pub fn bandwidth_mbps(&self) -> f64 {
        let mut s = self.state.lock().unwrap();
        s.apply_pending(self.clock.now());
        s.bandwidth_mbps
    }

    pub fn latency(&self) -> Duration {
        self.state.lock().unwrap().latency
    }

    pub fn bytes_sent(&self) -> u64 {
        self.state.lock().unwrap().bytes_sent
    }

    pub fn transfers(&self) -> u64 {
        self.state.lock().unwrap().transfers
    }

    /// Total chunks shipped across all transfers.
    pub fn chunks(&self) -> u64 {
        self.state.lock().unwrap().chunks
    }
}

/// Default transfer chunk size: `NEUKONFIG_CHUNK_BYTES`, falling back to
/// 64 KiB (unset, unparsable, or <= 0 all mean the default).
pub fn default_chunk_bytes() -> usize {
    parse_chunk_bytes(std::env::var("NEUKONFIG_CHUNK_BYTES").ok().as_deref())
}

pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

fn parse_chunk_bytes(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|b| *b > 0)
        .unwrap_or(DEFAULT_CHUNK_BYTES)
}

/// latency + bytes*8/bandwidth — shared by the live link and the analytic
/// planner (both must agree or the planner would mispredict splits).
pub fn transfer_time(bytes: usize, bandwidth_mbps: f64, latency: Duration) -> Duration {
    let serialisation = (bytes as f64 * 8.0) / (bandwidth_mbps * 1e6);
    latency + Duration::from_secs_f64(serialisation)
}

/// A timed bandwidth trace: `(at, mbps)` events applied in order.
#[derive(Debug, Clone)]
pub struct Schedule {
    events: Vec<(Duration, f64)>,
    next: usize,
}

impl Schedule {
    pub fn new(mut events: Vec<(Duration, f64)>) -> Self {
        events.sort_by_key(|e| e.0);
        Schedule { events, next: 0 }
    }

    /// The paper's experiment trace: toggle 20 <-> 5 Mbps every `period`.
    pub fn toggle(high: f64, low: f64, period: Duration, cycles: usize) -> Self {
        let mut ev = Vec::new();
        for i in 1..=cycles {
            ev.push((period * i as u32, if i % 2 == 1 { low } else { high }));
        }
        Schedule::new(ev)
    }

    /// Pop all events due at or before `now`; returns the latest one.
    pub fn poll(&mut self, now: Duration) -> Option<f64> {
        let mut last = None;
        while self.next < self.events.len() && self.events[self.next].0 <= now {
            last = Some(self.events[self.next].1);
            self.next += 1;
        }
        last
    }

    pub fn peek_next(&self) -> Option<(Duration, f64)> {
        self.events.get(self.next).copied()
    }

    pub fn is_done(&self) -> bool {
        self.next >= self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_link(mbps: f64) -> Link {
        Link::new(Clock::simulated(), mbps, Duration::from_millis(20))
    }

    #[test]
    fn transfer_time_model() {
        // 20 Mbps, 1 MB payload: 20ms + 8e6/20e6 s = 20ms + 400ms.
        let t = transfer_time(1_000_000, 20.0, Duration::from_millis(20));
        assert!((t.as_secs_f64() - 0.42).abs() < 1e-9);
    }

    #[test]
    fn slower_link_is_slower() {
        let l = sim_link(20.0);
        let fast = l.transfer_time(500_000);
        l.set_bandwidth(5.0);
        let slow = l.transfer_time(500_000);
        assert!(slow > fast * 3); // 4x serialisation, same latency
    }

    #[test]
    fn transfer_advances_clock() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 20.0, Duration::from_millis(20));
        let t0 = clock.now();
        let d = l.transfer(1_000_000);
        assert!(clock.now() - t0 >= d);
        assert_eq!(l.bytes_sent(), 1_000_000);
        assert_eq!(l.transfers(), 1);
    }

    #[test]
    fn fifo_contention_accumulates() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        // 1 MB at 8 Mbps = 1 s each; three sequential transfers queue.
        l.transfer(1_000_000);
        l.transfer(1_000_000);
        l.transfer(1_000_000);
        assert!(clock.now() >= Duration::from_secs(3));
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let l = sim_link(20.0);
        assert_eq!(l.transfer_time(0), Duration::from_millis(20));
    }

    #[test]
    fn chunked_cost_matches_unchunked_at_constant_bandwidth() {
        // Segment grouping: with no rate change, any chunk size must cost
        // bit-identically to the pre-chunking model.
        let expect = transfer_time(1_000_000, 20.0, Duration::from_millis(20));
        for chunk in [1_000_000, 65_536, 4096, 1_000_001, 1] {
            let l = sim_link(20.0);
            assert_eq!(l.transfer_chunked(1_000_000, chunk), expect, "chunk {chunk}");
        }
        let l = sim_link(20.0);
        assert_eq!(l.transfer(1_000_000), expect);
        l.transfer_chunked(1_000_000, 4096);
        assert_eq!(l.chunks(), 1_000_000usize.div_ceil(DEFAULT_CHUNK_BYTES) as u64 + 245);
        assert_eq!(l.transfers(), 2);
    }

    #[test]
    fn scheduled_rate_drop_reprices_remaining_chunks() {
        // Regression (stale-bandwidth costing): 2 MB at 8 Mbps is 2 s when
        // the whole payload is priced at submission-time bandwidth. With
        // the rate halving at t = 1 s, the chunks serialised after the
        // change must pay 4 Mbps: 16 x 64 KiB chunks (1,048,576 B) fit
        // before the event, the remaining 951,424 B cost twice as much —
        // ~2.951 s total.
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        l.schedule_bandwidth(Duration::from_secs(1), 4.0);
        let t = l.transfer_chunked(2_000_000, 65_536);
        assert!(
            t > Duration::from_secs(2),
            "transfer still priced at the stale submission bandwidth: {t:?}"
        );
        assert!(
            t >= Duration::from_secs_f64(2.9) && t <= Duration::from_secs_f64(3.0),
            "repriced cost off the chunk-granular model: {t:?}"
        );
        // The event has fired; later reads and transfers see 4 Mbps.
        assert_eq!(l.bandwidth_mbps(), 4.0);
    }

    #[test]
    fn scheduled_event_before_start_covers_whole_transfer() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 8.0, Duration::ZERO);
        l.schedule_bandwidth(Duration::ZERO, 4.0);
        // 1 MB entirely at the new 4 Mbps rate: 2 s exactly.
        let t = l.transfer_chunked(1_000_000, 65_536);
        assert_eq!(t, transfer_time(1_000_000, 4.0, Duration::ZERO));
    }

    #[test]
    fn scheduled_rate_rise_cheapens_the_tail() {
        let clock = Clock::simulated();
        let l = Link::new(clock.clone(), 4.0, Duration::ZERO);
        // 2 MB at 4 Mbps is 4 s flat; doubling the rate at t = 1 s leaves
        // ~1.5 MB to serialise at 8 Mbps: ~2.5 s total.
        l.schedule_bandwidth(Duration::from_secs(1), 8.0);
        let t = l.transfer_chunked(2_000_000, 65_536);
        assert!(t < Duration::from_secs(3), "tail not repriced upward: {t:?}");
        assert!(t > Duration::from_secs(2), "{t:?}");
    }

    #[test]
    fn chunk_bytes_parsing() {
        assert_eq!(parse_chunk_bytes(None), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("")), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("nope")), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("0")), DEFAULT_CHUNK_BYTES);
        assert_eq!(parse_chunk_bytes(Some("4096")), 4096);
        assert_eq!(parse_chunk_bytes(Some(" 128 ")), 128);
    }

    #[test]
    fn schedule_polls_in_order() {
        let mut s = Schedule::new(vec![
            (Duration::from_secs(2), 5.0),
            (Duration::from_secs(1), 10.0),
        ]);
        assert_eq!(s.poll(Duration::from_millis(500)), None);
        assert_eq!(s.poll(Duration::from_secs(1)), Some(10.0));
        assert_eq!(s.poll(Duration::from_secs(5)), Some(5.0));
        assert!(s.is_done());
    }

    #[test]
    fn schedule_poll_skips_to_latest() {
        let mut s = Schedule::new(vec![
            (Duration::from_secs(1), 10.0),
            (Duration::from_secs(2), 5.0),
        ]);
        // Both events due: the latest wins.
        assert_eq!(s.poll(Duration::from_secs(3)), Some(5.0));
    }

    #[test]
    fn toggle_alternates() {
        let s = Schedule::toggle(20.0, 5.0, Duration::from_secs(10), 4);
        let bws: Vec<f64> = s.events.iter().map(|e| e.1).collect();
        assert_eq!(bws, vec![5.0, 20.0, 5.0, 20.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bandwidth() {
        sim_link(0.0);
    }
}
