//! Container lifecycle + memory accounting — the Docker analogue.
//!
//! The paper runs each edge-cloud pipeline inside Docker containers and its
//! downtime equations are dominated by container control-plane operations
//! (pause/unpause, image start) plus model load. This module simulates that
//! control plane: lifecycle transitions cost calibrated time on the
//! experiment clock ([`crate::config::ContainerCosts`]), the optimised
//! 575 MB base image is cached after first use (paper §IV-B), and a
//! [`MemoryLedger`] tracks the per-host memory of Table I including the
//! transient peak during Scenario B Case 1 switching.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::clock::Clock;
use crate::config::ContainerCosts;
use crate::util::sync::lock_clean;

/// Simulated memory accounting for one host (MB granularity).
#[derive(Debug)]
pub struct MemoryLedger {
    total_mb: f64,
    state: Mutex<LedgerState>,
}

#[derive(Debug, Default)]
struct LedgerState {
    in_use_mb: f64,
    peak_mb: f64,
    entries: Vec<(u64, String, f64)>,
    next_id: u64,
}

/// RAII handle for a reservation; dropping releases the memory.
pub struct Reservation {
    ledger: Arc<MemoryLedger>,
    id: u64,
    pub mb: f64,
}

impl std::fmt::Debug for Reservation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Reservation({} MB)", self.mb)
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        let mut s = lock_clean(&self.ledger.state);
        s.in_use_mb -= self.mb;
        s.entries.retain(|(id, _, _)| *id != self.id);
    }
}

impl MemoryLedger {
    pub fn new(total_mb: f64) -> Arc<Self> {
        Arc::new(MemoryLedger { total_mb, state: Mutex::new(LedgerState::default()) })
    }

    /// Reserve `mb`; fails if the host would exceed its physical memory —
    /// this is what produces the paper's "no results at <=10% memory
    /// availability" gap (Fig 11).
    pub fn reserve(self: &Arc<Self>, label: &str, mb: f64) -> Result<Reservation> {
        let mut s = lock_clean(&self.state);
        if s.in_use_mb + mb > self.total_mb + 1e-9 {
            bail!(
                "OOM on ledger: {label} needs {mb:.1} MB, {:.1}/{:.1} MB in use",
                s.in_use_mb,
                self.total_mb
            );
        }
        s.in_use_mb += mb;
        s.peak_mb = s.peak_mb.max(s.in_use_mb);
        let id = s.next_id;
        s.next_id += 1;
        s.entries.push((id, label.to_string(), mb));
        Ok(Reservation { ledger: Arc::clone(self), id, mb })
    }

    pub fn in_use_mb(&self) -> f64 {
        lock_clean(&self.state).in_use_mb
    }

    pub fn peak_mb(&self) -> f64 {
        lock_clean(&self.state).peak_mb
    }

    pub fn total_mb(&self) -> f64 {
        self.total_mb
    }

    pub fn available_mb(&self) -> f64 {
        self.total_mb - self.in_use_mb()
    }

    /// Labelled breakdown (Table I rows).
    pub fn entries(&self) -> Vec<(String, f64)> {
        lock_clean(&self.state)
            .entries
            .iter()
            .map(|(_, l, m)| (l.clone(), *m))
            .collect()
    }

    pub fn reset_peak(&self) {
        let mut s = lock_clean(&self.state);
        s.peak_mb = s.in_use_mb;
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    Running,
    Paused,
    Stopped,
}

/// A simulated container: a memory reservation + a lifecycle state.
pub struct Container {
    pub id: u64,
    pub image: String,
    state: Mutex<ContainerState>,
    _mem: Reservation,
}

impl Container {
    pub fn state(&self) -> ContainerState {
        *lock_clean(&self.state)
    }

    /// Ledger-attributed footprint of this container (its reservation).
    pub fn memory_mb(&self) -> f64 {
        self._mem.mb
    }
}

/// One host's container engine ("Docker daemon") — edge or cloud.
pub struct ContainerHost {
    pub name: String,
    pub ledger: Arc<MemoryLedger>,
    costs: ContainerCosts,
    clock: Clock,
    image_cache: Mutex<HashSet<String>>,
    next_id: AtomicU64,
}

impl ContainerHost {
    pub fn new(
        name: impl Into<String>,
        total_mb: f64,
        costs: ContainerCosts,
        clock: Clock,
    ) -> Arc<Self> {
        Arc::new(ContainerHost {
            name: name.into(),
            ledger: MemoryLedger::new(total_mb),
            costs,
            clock,
            image_cache: Mutex::new(HashSet::new()),
            next_id: AtomicU64::new(1),
        })
    }

    /// Start a container. The first start of an image pays the image-start
    /// cost; the paper's optimisation pre-installs TF/pyzmq in a cached
    /// base image (575 MB) so subsequent starts are warm.
    pub fn start(
        self: &Arc<Self>,
        image: &str,
        app_mb: f64,
    ) -> Result<Arc<Container>> {
        let warm = lock_clean(&self.image_cache).contains(image);
        if !warm {
            // Cold image: pay the full start cost once, then cache.
            self.clock.sleep(self.costs.container_start);
            lock_clean(&self.image_cache).insert(image.to_string());
        } else {
            self.clock.sleep(self.costs.container_start);
        }
        let mem = self.ledger.reserve(&format!("container:{image}"), app_mb)?;
        Ok(Arc::new(Container {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image: image.to_string(),
            state: Mutex::new(ContainerState::Running),
            _mem: mem,
        }))
    }

    /// Pre-warm the image cache (paper: base image stored in local cache).
    pub fn warm_image(&self, image: &str) {
        lock_clean(&self.image_cache).insert(image.to_string());
    }

    pub fn pause(&self, c: &Container) {
        self.clock.sleep(self.costs.pause);
        *lock_clean(&c.state) = ContainerState::Paused;
    }

    pub fn unpause(&self, c: &Container) {
        self.clock.sleep(self.costs.unpause);
        *lock_clean(&c.state) = ContainerState::Running;
    }

    pub fn stop(&self, c: &Container) {
        self.clock.sleep(self.costs.container_stop);
        *lock_clean(&c.state) = ContainerState::Stopped;
    }

    pub fn costs(&self) -> &ContainerCosts {
        &self.costs
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn host() -> Arc<ContainerHost> {
        ContainerHost::new("edge", 2000.0, ContainerCosts::default(), Clock::simulated())
    }

    #[test]
    fn reserve_and_release() {
        let l = MemoryLedger::new(1000.0);
        let r = l.reserve("a", 600.0).unwrap();
        assert_eq!(l.in_use_mb(), 600.0);
        drop(r);
        assert_eq!(l.in_use_mb(), 0.0);
        assert_eq!(l.peak_mb(), 600.0);
    }

    #[test]
    fn oom_rejected() {
        let l = MemoryLedger::new(1000.0);
        let _a = l.reserve("a", 763.1).unwrap();
        assert!(l.reserve("b", 763.1).is_err());
    }

    #[test]
    fn peak_tracks_transient() {
        // Scenario B Case 1: second pipeline only during switching.
        let l = MemoryLedger::new(2000.0);
        let _a = l.reserve("p1", 763.1).unwrap();
        {
            let _b = l.reserve("p2", 763.1).unwrap();
            assert!((l.in_use_mb() - 1526.2).abs() < 1e-9);
        }
        assert!((l.in_use_mb() - 763.1).abs() < 1e-9);
        assert!((l.peak_mb() - 1526.2).abs() < 1e-9);
    }

    #[test]
    fn entries_labelled() {
        let l = MemoryLedger::new(1000.0);
        let _r = l.reserve("pipeline-1", 100.0).unwrap();
        assert_eq!(l.entries(), vec![("pipeline-1".to_string(), 100.0)]);
    }

    #[test]
    fn container_lifecycle_costs_time() {
        let h = host();
        let clock = h.clock().clone();
        let t0 = clock.now();
        let c = h.start("neukonfig:base", 763.1).unwrap();
        assert!(clock.now() - t0 >= Duration::from_millis(600));
        assert_eq!(c.state(), ContainerState::Running);
        h.pause(&c);
        assert_eq!(c.state(), ContainerState::Paused);
        h.unpause(&c);
        assert_eq!(c.state(), ContainerState::Running);
        h.stop(&c);
        assert_eq!(c.state(), ContainerState::Stopped);
    }

    #[test]
    fn stopping_releases_memory() {
        let h = host();
        let c = h.start("img", 500.0).unwrap();
        assert_eq!(h.ledger.in_use_mb(), 500.0);
        h.stop(&c);
        drop(c);
        assert_eq!(h.ledger.in_use_mb(), 0.0);
    }

    #[test]
    fn container_reports_reservation() {
        let h = host();
        let c = h.start("img", 321.0).unwrap();
        assert_eq!(c.memory_mb(), 321.0);
    }

    #[test]
    fn container_start_oom_propagates() {
        let h = ContainerHost::new("edge", 700.0, ContainerCosts::zero(), Clock::simulated());
        assert!(h.start("img", 763.1).is_err());
    }
}
