//! Edge-resource stress control — the stress-ng analogue.
//!
//! The paper sweeps CPU availability (25..100 %) and memory availability
//! (10..100 %) on the edge server with stress-ng (Figs 11-15). Here a
//! [`StressProfile`] (a) scales the edge domain's compute-time dilation and
//! (b) pre-reserves "stressor" memory on the edge ledger so pipeline
//! admission fails when what remains cannot hold the model — reproducing
//! the paper's empty cells at <=10 % memory availability.

use std::sync::Arc;

use anyhow::Result;

use crate::container::{MemoryLedger, Reservation};

/// A point in the paper's CPU x memory availability grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StressProfile {
    /// Fraction of edge CPU available to the pipeline (0, 1].
    pub cpu_avail: f64,
    /// Fraction of edge memory available to the pipeline (0, 1].
    pub mem_avail: f64,
}

impl StressProfile {
    pub fn none() -> Self {
        StressProfile { cpu_avail: 1.0, mem_avail: 1.0 }
    }

    pub fn new(cpu_avail: f64, mem_avail: f64) -> Self {
        assert!(cpu_avail > 0.0 && cpu_avail <= 1.0, "cpu_avail in (0,1]");
        assert!(mem_avail > 0.0 && mem_avail <= 1.0, "mem_avail in (0,1]");
        StressProfile { cpu_avail, mem_avail }
    }

    /// The paper's grid: CPU {25,50,75,100}% x memory {10,25,50,75,100}%.
    pub fn paper_grid() -> Vec<StressProfile> {
        let mut grid = Vec::new();
        for &cpu in &[0.25, 0.5, 0.75, 1.0] {
            for &mem in &[0.10, 0.25, 0.50, 0.75, 1.0] {
                grid.push(StressProfile::new(cpu, mem));
            }
        }
        grid
    }

    /// Effective edge compute scale given the domain's base scale.
    pub fn edge_scale(&self, base: f64) -> f64 {
        base * self.cpu_avail
    }
}

/// Holds the stressor's memory on the edge ledger for the profile's
/// lifetime (RAII, like a running stress-ng --vm).
pub struct AppliedStress {
    pub profile: StressProfile,
    _mem_hog: Option<Reservation>,
}

/// Apply `profile` to an edge ledger: reserves the unavailable fraction.
pub fn apply(ledger: &Arc<MemoryLedger>, profile: StressProfile) -> Result<AppliedStress> {
    let hog_mb = ledger.total_mb() * (1.0 - profile.mem_avail);
    let _mem_hog = if hog_mb > 0.0 {
        Some(ledger.reserve("stress-ng:vm", hog_mb)?)
    } else {
        None
    };
    Ok(AppliedStress { profile, _mem_hog })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_matches_paper_axes() {
        let g = StressProfile::paper_grid();
        assert_eq!(g.len(), 20);
        assert!(g.contains(&StressProfile::new(0.25, 0.10)));
        assert!(g.contains(&StressProfile::new(1.0, 1.0)));
    }

    #[test]
    fn mem_hog_blocks_pipeline_at_10pct() {
        // 8 GB edge, 10% available = 819 MB free; one 763 MB pipeline fits,
        // but in the paper the DNN could not run at 10% — that corresponds
        // to the *model partition* footprint; use 2 pipelines to see OOM.
        let ledger = MemoryLedger::new(8192.0);
        let _s = apply(&ledger, StressProfile::new(1.0, 0.10)).unwrap();
        assert!(ledger.available_mb() < 820.0);
        let _p1 = ledger.reserve("pipeline", 763.1).unwrap();
        assert!(ledger.reserve("pipeline2", 763.1).is_err());
    }

    #[test]
    fn release_on_drop() {
        let ledger = MemoryLedger::new(1000.0);
        {
            let _s = apply(&ledger, StressProfile::new(1.0, 0.5)).unwrap();
            assert_eq!(ledger.in_use_mb(), 500.0);
        }
        assert_eq!(ledger.in_use_mb(), 0.0);
    }

    #[test]
    fn cpu_scale_composes() {
        let p = StressProfile::new(0.25, 1.0);
        assert_eq!(p.edge_scale(1.0), 0.25);
        assert_eq!(p.edge_scale(2.0), 0.5);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_cpu() {
        StressProfile::new(0.0, 1.0);
    }

    #[test]
    fn full_availability_reserves_nothing() {
        let ledger = MemoryLedger::new(1000.0);
        let _s = apply(&ledger, StressProfile::none()).unwrap();
        assert_eq!(ledger.in_use_mb(), 0.0);
    }
}
