//! Micro-benchmark harness — substrate module (no `criterion` offline).
//!
//! Provides warmup + timed iterations with summary statistics, and a
//! `Report` that renders the paper-vs-measured tables every `rust/benches/`
//! binary prints. Kept in the library so benches, examples, and the CLI
//! share one implementation.

use std::time::Duration;

use crate::clock::Stopwatch;
use crate::metrics::Table;
use crate::util::stats::Summary;

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Hard cap on total measurement time (whichever comes first).
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            iters: 20,
            max_time: Duration::from_secs(30),
        }
    }
}

impl BenchConfig {
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(10) }
    }

    /// Honour `NEUKONFIG_BENCH_QUICK=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("NEUKONFIG_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.summary.mean)
    }
}

/// Run `f` under the harness, timing each iteration.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Stopwatch::start();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Stopwatch::start();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one iteration"),
    }
}

/// Run `f` where the iteration *returns* its measured duration — used when
/// the interesting time is on the experiment clock (simulated components),
/// not host wall time.
pub fn bench_measured(
    name: &str,
    cfg: &BenchConfig,
    mut f: impl FnMut() -> Duration,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Stopwatch::start();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        samples.push(f().as_secs_f64());
        if started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one iteration"),
    }
}

/// Render bench results as machine-readable JSON (the `BENCH_*.json`
/// baselines future PRs diff against for a perf trajectory). Hand-rolled —
/// no serde offline; times are seconds, matching [`Summary`].
pub fn results_to_json(title: &str, results: &[BenchResult]) -> String {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"title\": \"{}\",\n", esc(title)));
    out.push_str("  \"unit\": \"seconds\",\n");
    out.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let s = &r.summary;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"n\": {}, \"mean\": {}, \"std_dev\": {}, \
             \"min\": {}, \"max\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}}}{}\n",
            esc(&r.name),
            s.n,
            s.mean,
            s.std_dev,
            s.min,
            s.max,
            s.p50,
            s.p95,
            s.p99,
            if i + 1 == results.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write a JSON baseline file (see [`results_to_json`]).
pub fn write_json_baseline(
    path: impl AsRef<std::path::Path>,
    title: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    std::fs::write(path, results_to_json(title, results))
}

/// One row of a baseline-vs-current comparison (the CI regression gate).
#[derive(Debug, Clone, PartialEq)]
pub struct RowRegression {
    pub name: String,
    /// Mean of the committed baseline, seconds.
    pub baseline_mean: f64,
    /// Mean of the current run, seconds.
    pub current_mean: f64,
    /// `current / baseline` (1.0 = unchanged, 2.0 = twice as slow).
    pub ratio: f64,
    /// True when `ratio > 1 + tolerance`.
    pub regressed: bool,
}

/// Diff two `BENCH_*.json` documents (the [`results_to_json`] format) on
/// row means. Rows are matched by name; rows present in only one file are
/// skipped — renamed or newly added benches must not fail the gate.
/// `tolerance` is fractional: 0.15 flags rows more than 15% slower than
/// the baseline. Returns every matched row (callers filter on
/// `regressed`); errors only on malformed JSON.
pub fn compare_baselines(
    baseline_json: &str,
    current_json: &str,
    tolerance: f64,
) -> anyhow::Result<Vec<RowRegression>> {
    fn means(doc: &str) -> anyhow::Result<Vec<(String, f64)>> {
        let v = crate::util::json::parse(doc)
            .map_err(|e| anyhow::anyhow!("malformed bench JSON: {e:?}"))?;
        let rows = v
            .get("results")
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("bench JSON has no results array"))?;
        rows.iter()
            .map(|r| {
                let name = r
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("bench row without a name"))?;
                let mean = r
                    .get("mean")
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("bench row {name} without a mean"))?;
                Ok((name.to_string(), mean))
            })
            .collect()
    }
    let baseline = means(baseline_json)?;
    let current = means(current_json)?;
    let mut out = Vec::new();
    for (name, baseline_mean) in baseline {
        let Some((_, current_mean)) = current.iter().find(|(n, _)| *n == name) else {
            continue;
        };
        let ratio = if baseline_mean > 0.0 {
            current_mean / baseline_mean
        } else {
            1.0
        };
        out.push(RowRegression {
            name,
            baseline_mean,
            current_mean: *current_mean,
            ratio,
            regressed: ratio > 1.0 + tolerance,
        });
    }
    Ok(out)
}

/// Whether a `BENCH_*.json` baseline document carries `"provisional":
/// true` — a hand-seeded placeholder committed to arm the CI gate before a
/// reference machine has produced real numbers. Regressions against a
/// provisional baseline are reported but must not fail the gate; replacing
/// the file with real `cargo bench --bench hot_path` output (which never
/// writes the flag) makes the gate authoritative.
pub fn baseline_is_provisional(doc: &str) -> bool {
    crate::util::json::parse(doc)
        .map(|v| matches!(*v.get("provisional"), crate::util::json::Value::Bool(true)))
        .unwrap_or(false)
}

/// Paper-vs-measured report printed by each bench binary.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    tables: Vec<Table>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), ..Default::default() }
    }

    pub fn table(&mut self, t: Table) {
        self.tables.push(t);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {}\n\n", self.title);
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarises() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(5) };
        let mut count = 0;
        let r = bench("noop", &cfg, || count += 1);
        assert_eq!(count, 6); // 1 warmup + 5 timed
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn bench_measured_uses_returned_duration() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(5) };
        let r = bench_measured("fixed", &cfg, || Duration::from_millis(250));
        assert!((r.summary.mean - 0.25).abs() < 1e-9);
        assert_eq!(r.summary.std_dev, 0.0);
    }

    #[test]
    fn report_renders_tables_and_notes() {
        let mut rep = Report::new("Fig X");
        let mut t = Table::new("t", &["col"]);
        t.row(vec!["v".into()]);
        rep.table(t);
        rep.note("shape matches the paper");
        let md = rep.render();
        assert!(md.contains("## Fig X"));
        assert!(md.contains("| v |"));
        assert!(md.contains("> shape"));
    }

    #[test]
    fn json_baseline_round_trips() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(5) };
        let a = bench_measured("op \"a\"", &cfg, || Duration::from_millis(10));
        let b = bench_measured("op-b", &cfg, || Duration::from_millis(20));
        let json = results_to_json("hot_path", &[a, b]);
        let v = crate::util::json::parse(&json).expect("valid json");
        assert_eq!(v.get("title").as_str(), Some("hot_path"));
        let results = v.get("results").as_array().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").as_str(), Some("op \"a\""));
        assert!((results[1].get("mean").as_f64().unwrap() - 0.02).abs() < 1e-9);
        assert_eq!(results[0].get("n").as_usize(), Some(3));
    }

    fn fixed(name: &str, ms: u64) -> BenchResult {
        let cfg = BenchConfig { warmup_iters: 0, iters: 2, max_time: Duration::from_secs(5) };
        bench_measured(name, &cfg, || Duration::from_millis(ms))
    }

    #[test]
    fn compare_flags_only_regressions_beyond_tolerance() {
        let base = results_to_json("t", &[fixed("a", 100), fixed("b", 100), fixed("c", 100)]);
        // a: 10% slower (inside 15%), b: 30% slower (outside), c: faster.
        let cur = results_to_json("t", &[fixed("a", 110), fixed("b", 130), fixed("c", 50)]);
        let rows = compare_baselines(&base, &cur, 0.15).unwrap();
        assert_eq!(rows.len(), 3);
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        assert!(!by_name("a").regressed);
        assert!(by_name("b").regressed);
        assert!((by_name("b").ratio - 1.3).abs() < 1e-9);
        assert!(!by_name("c").regressed);
    }

    #[test]
    fn compare_skips_unmatched_rows() {
        // Renames/additions/removals never fail the gate.
        let base = results_to_json("t", &[fixed("kept", 100), fixed("removed", 10)]);
        let cur = results_to_json("t", &[fixed("kept", 100), fixed("added", 900)]);
        let rows = compare_baselines(&base, &cur, 0.15).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].name, "kept");
        assert!(!rows[0].regressed);
    }

    #[test]
    fn provisional_flag_detection() {
        assert!(baseline_is_provisional(
            "{\"title\": \"t\", \"provisional\": true, \"results\": []}"
        ));
        assert!(!baseline_is_provisional(
            "{\"title\": \"t\", \"provisional\": false, \"results\": []}"
        ));
        // Absent flag (the results_to_json output) and malformed docs are
        // both authoritative/non-provisional.
        assert!(!baseline_is_provisional(&results_to_json("t", &[fixed("a", 10)])));
        assert!(!baseline_is_provisional("{oops"));
    }

    #[test]
    fn compare_rejects_malformed_json() {
        let good = results_to_json("t", &[fixed("a", 10)]);
        assert!(compare_baselines("{oops", &good, 0.15).is_err());
        assert!(compare_baselines(&good, "{\"no\": \"results\"}", 0.15).is_err());
    }

    #[test]
    fn max_time_caps_iterations() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            iters: 1000,
            max_time: Duration::from_millis(30),
        };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(10)));
        assert!(r.summary.n < 1000);
    }
}
