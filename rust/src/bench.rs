//! Micro-benchmark harness — substrate module (no `criterion` offline).
//!
//! Provides warmup + timed iterations with summary statistics, and a
//! `Report` that renders the paper-vs-measured tables every `rust/benches/`
//! binary prints. Kept in the library so benches, examples, and the CLI
//! share one implementation.

use std::time::{Duration, Instant};

use crate::metrics::Table;
use crate::util::stats::Summary;

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Hard cap on total measurement time (whichever comes first).
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            iters: 20,
            max_time: Duration::from_secs(30),
        }
    }
}

impl BenchConfig {
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(10) }
    }

    /// Honour `NEUKONFIG_BENCH_QUICK=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("NEUKONFIG_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.summary.mean)
    }
}

/// Run `f` under the harness, timing each iteration.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one iteration"),
    }
}

/// Run `f` where the iteration *returns* its measured duration — used when
/// the interesting time is on the experiment clock (simulated components),
/// not host wall time.
pub fn bench_measured(
    name: &str,
    cfg: &BenchConfig,
    mut f: impl FnMut() -> Duration,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        samples.push(f().as_secs_f64());
        if started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one iteration"),
    }
}

/// Render bench results as machine-readable JSON (the `BENCH_*.json`
/// baselines future PRs diff against for a perf trajectory). Hand-rolled —
/// no serde offline; times are seconds, matching [`Summary`].
pub fn results_to_json(title: &str, results: &[BenchResult]) -> String {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"title\": \"{}\",\n", esc(title)));
    out.push_str("  \"unit\": \"seconds\",\n");
    out.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let s = &r.summary;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"n\": {}, \"mean\": {}, \"std_dev\": {}, \
             \"min\": {}, \"max\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}}}{}\n",
            esc(&r.name),
            s.n,
            s.mean,
            s.std_dev,
            s.min,
            s.max,
            s.p50,
            s.p95,
            s.p99,
            if i + 1 == results.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write a JSON baseline file (see [`results_to_json`]).
pub fn write_json_baseline(
    path: impl AsRef<std::path::Path>,
    title: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    std::fs::write(path, results_to_json(title, results))
}

/// Paper-vs-measured report printed by each bench binary.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    tables: Vec<Table>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), ..Default::default() }
    }

    pub fn table(&mut self, t: Table) {
        self.tables.push(t);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {}\n\n", self.title);
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarises() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(5) };
        let mut count = 0;
        let r = bench("noop", &cfg, || count += 1);
        assert_eq!(count, 6); // 1 warmup + 5 timed
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn bench_measured_uses_returned_duration() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(5) };
        let r = bench_measured("fixed", &cfg, || Duration::from_millis(250));
        assert!((r.summary.mean - 0.25).abs() < 1e-9);
        assert_eq!(r.summary.std_dev, 0.0);
    }

    #[test]
    fn report_renders_tables_and_notes() {
        let mut rep = Report::new("Fig X");
        let mut t = Table::new("t", &["col"]);
        t.row(vec!["v".into()]);
        rep.table(t);
        rep.note("shape matches the paper");
        let md = rep.render();
        assert!(md.contains("## Fig X"));
        assert!(md.contains("| v |"));
        assert!(md.contains("> shape"));
    }

    #[test]
    fn json_baseline_round_trips() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(5) };
        let a = bench_measured("op \"a\"", &cfg, || Duration::from_millis(10));
        let b = bench_measured("op-b", &cfg, || Duration::from_millis(20));
        let json = results_to_json("hot_path", &[a, b]);
        let v = crate::util::json::parse(&json).expect("valid json");
        assert_eq!(v.get("title").as_str(), Some("hot_path"));
        let results = v.get("results").as_array().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").as_str(), Some("op \"a\""));
        assert!((results[1].get("mean").as_f64().unwrap() - 0.02).abs() < 1e-9);
        assert_eq!(results[0].get("n").as_usize(), Some(3));
    }

    #[test]
    fn max_time_caps_iterations() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            iters: 1000,
            max_time: Duration::from_millis(30),
        };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(10)));
        assert!(r.summary.n < 1000);
    }
}
