//! Micro-benchmark harness — substrate module (no `criterion` offline).
//!
//! Provides warmup + timed iterations with summary statistics, and a
//! `Report` that renders the paper-vs-measured tables every `rust/benches/`
//! binary prints. Kept in the library so benches, examples, and the CLI
//! share one implementation.

use std::time::{Duration, Instant};

use crate::metrics::Table;
use crate::util::stats::Summary;

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Hard cap on total measurement time (whichever comes first).
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            iters: 20,
            max_time: Duration::from_secs(30),
        }
    }
}

impl BenchConfig {
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(10) }
    }

    /// Honour `NEUKONFIG_BENCH_QUICK=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("NEUKONFIG_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.summary.mean)
    }
}

/// Run `f` under the harness, timing each iteration.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one iteration"),
    }
}

/// Run `f` where the iteration *returns* its measured duration — used when
/// the interesting time is on the experiment clock (simulated components),
/// not host wall time.
pub fn bench_measured(
    name: &str,
    cfg: &BenchConfig,
    mut f: impl FnMut() -> Duration,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        samples.push(f().as_secs_f64());
        if started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one iteration"),
    }
}

/// Paper-vs-measured report printed by each bench binary.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    tables: Vec<Table>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), ..Default::default() }
    }

    pub fn table(&mut self, t: Table) {
        self.tables.push(t);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {}\n\n", self.title);
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarises() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(5) };
        let mut count = 0;
        let r = bench("noop", &cfg, || count += 1);
        assert_eq!(count, 6); // 1 warmup + 5 timed
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn bench_measured_uses_returned_duration() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(5) };
        let r = bench_measured("fixed", &cfg, || Duration::from_millis(250));
        assert!((r.summary.mean - 0.25).abs() < 1e-9);
        assert_eq!(r.summary.std_dev, 0.0);
    }

    #[test]
    fn report_renders_tables_and_notes() {
        let mut rep = Report::new("Fig X");
        let mut t = Table::new("t", &["col"]);
        t.row(vec!["v".into()]);
        rep.table(t);
        rep.note("shape matches the paper");
        let md = rep.render();
        assert!(md.contains("## Fig X"));
        assert!(md.contains("| v |"));
        assert!(md.contains("> shape"));
    }

    #[test]
    fn max_time_caps_iterations() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            iters: 1000,
            max_time: Duration::from_millis(30),
        };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(10)));
        assert!(r.summary.n < 1000);
    }
}
