//! Model manifests: the Rust-side view of the artifacts emitted by
//! `python/compile/aot.py`.
//!
//! A model is a chain of *partition units* (layers for VGG-19, blocks for
//! MobileNetV2 — see the paper §II-A); each unit has its own HLO module,
//! parameter slice in `weights.bin`, and metadata (shapes, FLOPs, output
//! bytes). A partition at split `k` assigns units `[0, k)` to the edge and
//! `[k, N)` to the cloud; `k = 0` is cloud-only, `k = N` edge-only.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// One parameter tensor of a unit, with its slice in `weights.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// One partition unit (layer or block).
#[derive(Debug, Clone)]
pub struct LayerManifest {
    pub index: usize,
    pub name: String,
    pub kind: String,
    /// HLO text file, relative to the model directory.
    pub hlo: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub output_bytes: usize,
    pub flops: u64,
    pub params: Vec<ParamEntry>,
}

impl LayerManifest {
    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|p| p.size_bytes).sum()
    }
}

/// A fused-partition artifact pair (ablation; DESIGN.md).
#[derive(Debug, Clone, PartialEq)]
pub struct FusedEntry {
    pub split: usize,
    pub edge_hlo: Option<String>,
    pub cloud_hlo: Option<String>,
}

/// A full model manifest (one DNN).
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub weights_bytes: usize,
    pub total_flops: u64,
    pub layers: Vec<LayerManifest>,
    /// Fused-partition ablation artifacts (may be empty).
    pub fused: Vec<FusedEntry>,
    /// Directory holding the HLO files and weights.bin.
    pub dir: PathBuf,
}

impl ModelManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v, dir)
    }

    fn from_json(v: &Value, dir: PathBuf) -> Result<Self> {
        let name = req_str(v, "name")?;
        let layers_v = v
            .get("layers")
            .as_array()
            .context("manifest missing `layers`")?;
        let mut layers = Vec::with_capacity(layers_v.len());
        for (i, lv) in layers_v.iter().enumerate() {
            let layer = LayerManifest {
                index: lv.get("index").as_usize().context("layer missing index")?,
                name: req_str(lv, "name")?,
                kind: req_str(lv, "kind")?,
                hlo: req_str(lv, "hlo")?,
                input_shape: shape(lv.get("input_shape"))?,
                output_shape: shape(lv.get("output_shape"))?,
                output_bytes: lv
                    .get("output_bytes")
                    .as_usize()
                    .context("layer missing output_bytes")?,
                flops: lv.get("flops").as_i64().unwrap_or(0) as u64,
                params: params(lv.get("params"))?,
            };
            if layer.index != i {
                bail!("layer index {} out of order (expected {i})", layer.index);
            }
            layers.push(layer);
        }
        // Shape chaining invariant: unit k's output feeds unit k+1.
        for w in layers.windows(2) {
            if w[0].output_shape != w[1].input_shape {
                bail!(
                    "manifest shape mismatch: {}({:?}) -> {}({:?})",
                    w[0].name,
                    w[0].output_shape,
                    w[1].name,
                    w[1].input_shape
                );
            }
        }
        let fused = v
            .get("fused")
            .as_array()
            .map(|arr| {
                arr.iter()
                    .map(|f| {
                        Ok(FusedEntry {
                            split: f.get("split").as_usize().context("fused split")?,
                            edge_hlo: f.get("edge_hlo").as_str().map(str::to_owned),
                            cloud_hlo: f.get("cloud_hlo").as_str().map(str::to_owned),
                        })
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .transpose()?
            .unwrap_or_default();
        Ok(ModelManifest {
            name,
            input_shape: shape(v.get("input_shape"))?,
            weights_bytes: v
                .get("weights_bytes")
                .as_usize()
                .context("manifest missing weights_bytes")?,
            total_flops: v.get("total_flops").as_i64().unwrap_or(0) as u64,
            layers,
            fused,
            dir,
        })
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Valid split points: `0..=num_layers()`.
    pub fn valid_splits(&self) -> impl Iterator<Item = usize> {
        0..=self.layers.len()
    }

    /// Intermediate tensor size crossing the network for split `k` (bytes).
    /// `k = 0` ships the raw input; `k = N` ships the final output.
    pub fn transfer_bytes(&self, split: usize) -> usize {
        assert!(split <= self.layers.len(), "split {split} out of range");
        if split == 0 {
            self.input_shape.iter().product::<usize>() * 4
        } else {
            self.layers[split - 1].output_bytes
        }
    }

    /// [`Self::transfer_bytes`] as actually priced on the wire under a
    /// transfer codec (fp16 halves, int8 quarters + a 16-byte header).
    pub fn coded_transfer_bytes(&self, split: usize, codec: crate::codec::TransferCodec) -> usize {
        codec.encoded_bytes(self.transfer_bytes(split))
    }

    pub fn hlo_path(&self, index: usize) -> PathBuf {
        self.dir.join(&self.layers[index].hlo)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join("weights.bin")
    }

    /// Sum of parameter bytes over units `[range.start, range.end)`.
    pub fn param_bytes_in(&self, range: std::ops::Range<usize>) -> usize {
        self.layers[range].iter().map(|l| l.param_bytes()).sum()
    }
}

/// Index over all exported models (`artifacts/manifest.json`).
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub root: PathBuf,
    pub width: f64,
    pub hw: usize,
    pub models: Vec<String>,
}

impl ArtifactIndex {
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text)?;
        let models = v
            .get("models")
            .as_object()
            .context("index missing `models`")?
            .keys()
            .cloned()
            .collect();
        Ok(ArtifactIndex {
            root,
            width: v.get("width").as_f64().unwrap_or(1.0),
            hw: v.get("hw").as_usize().unwrap_or(0),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<ModelManifest> {
        if !self.models.iter().any(|m| m == name) {
            bail!(
                "model {name:?} not in artifacts (have: {:?})",
                self.models
            );
        }
        ModelManifest::load(self.root.join(name))
    }
}

/// Default artifacts dir: `$NEUKONFIG_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NEUKONFIG_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from the executable/cwd looking for artifacts/manifest.json.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .as_str()
        .map(str::to_owned)
        .with_context(|| format!("missing string field `{key}`"))
}

fn shape(v: &Value) -> Result<Vec<usize>> {
    v.as_array()
        .context("expected shape array")?
        .iter()
        .map(|d| d.as_usize().context("bad shape dim"))
        .collect()
}

fn params(v: &Value) -> Result<Vec<ParamEntry>> {
    let arr = match v.as_array() {
        Some(a) => a,
        None => return Ok(vec![]),
    };
    arr.iter()
        .map(|p| {
            Ok(ParamEntry {
                name: req_str(p, "name")?,
                shape: shape(p.get("shape"))?,
                offset_bytes: p
                    .get("offset_bytes")
                    .as_usize()
                    .context("param missing offset")?,
                size_bytes: p
                    .get("size_bytes")
                    .as_usize()
                    .context("param missing size")?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "name": "toy",
          "input_shape": [1, 4, 4, 3],
          "weights_bin": "weights.bin",
          "weights_bytes": 24,
          "total_flops": 100,
          "layers": [
            {"index": 0, "name": "conv1", "kind": "conv", "hlo": "layer_00.hlo.txt",
             "input_shape": [1, 4, 4, 3], "output_shape": [1, 4, 4, 2],
             "output_bytes": 128, "flops": 60,
             "params": [{"name": "conv1_w", "shape": [1, 3, 2], "offset_bytes": 0, "size_bytes": 24}]},
            {"index": 1, "name": "pool", "kind": "maxpool", "hlo": "layer_01.hlo.txt",
             "input_shape": [1, 4, 4, 2], "output_shape": [1, 2, 2, 2],
             "output_bytes": 32, "flops": 40, "params": []}
          ]
        }"#
    }

    fn parse_sample() -> ModelManifest {
        let v = json::parse(sample_manifest()).unwrap();
        ModelManifest::from_json(&v, PathBuf::from("/tmp/toy")).unwrap()
    }

    #[test]
    fn parses_layers_and_params() {
        let m = parse_sample();
        assert_eq!(m.num_layers(), 2);
        assert_eq!(m.layers[0].params[0].name, "conv1_w");
        assert_eq!(m.layers[0].param_bytes(), 24);
        assert_eq!(m.layers[1].params.len(), 0);
    }

    #[test]
    fn transfer_bytes_per_split() {
        let m = parse_sample();
        assert_eq!(m.transfer_bytes(0), 4 * 4 * 3 * 4); // raw input
        assert_eq!(m.transfer_bytes(1), 128);
        assert_eq!(m.transfer_bytes(2), 32);
    }

    #[test]
    #[should_panic]
    fn transfer_bytes_rejects_out_of_range() {
        parse_sample().transfer_bytes(3);
    }

    #[test]
    fn coded_transfer_bytes_follows_the_wire_model() {
        use crate::codec::TransferCodec;
        let m = parse_sample();
        assert_eq!(m.coded_transfer_bytes(1, TransferCodec::Fp32), 128);
        assert_eq!(m.coded_transfer_bytes(1, TransferCodec::Fp16), 64);
        assert_eq!(
            m.coded_transfer_bytes(1, TransferCodec::Int8),
            128 / 4 + crate::codec::INT8_HEADER_BYTES
        );
    }

    #[test]
    fn valid_splits_covers_all() {
        let m = parse_sample();
        let splits: Vec<_> = m.valid_splits().collect();
        assert_eq!(splits, vec![0, 1, 2]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = sample_manifest().replace("[1, 4, 4, 2], \"output_shape\": [1, 2, 2, 2]",
                                            "[1, 9, 9, 9], \"output_shape\": [1, 2, 2, 2]");
        let v = json::parse(&bad).unwrap();
        assert!(ModelManifest::from_json(&v, PathBuf::from(".")).is_err());
    }

    #[test]
    fn param_bytes_in_range() {
        let m = parse_sample();
        assert_eq!(m.param_bytes_in(0..1), 24);
        assert_eq!(m.param_bytes_in(1..2), 0);
        assert_eq!(m.param_bytes_in(0..2), 24);
    }
}
