//! Per-layer profiler and the Equation-1 latency model.
//!
//! §II of the paper profiles each layer's compute time on the edge and the
//! cloud plus the size of the tensor crossing each split point, then picks
//! the split minimising `T_inf = T_e + T_t + T_c` (Equation 1). This module
//! does the same against the real PJRT executables ([`measure`]) or from
//! manifest FLOPs when no artifacts are available ([`ModelProfile::analytic`],
//! used by pure-logic tests and fast sweeps).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::clock::Stopwatch;
use crate::codec::TransferCodec;
use crate::models::ModelManifest;
use crate::netsim::transfer_time;
use crate::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};

/// Profile of one partition unit.
#[derive(Debug, Clone, Default)]
pub struct LayerProfile {
    pub index: usize,
    pub name: String,
    pub kind: String,
    pub edge_time: Duration,
    pub cloud_time: Duration,
    pub output_bytes: usize,
    /// How many measured frames have been folded into `edge_time` /
    /// `cloud_time` (0 = pure analytic prior). Lets callers judge how much
    /// to trust an estimate before repartitioning on it.
    pub edge_observations: u64,
    pub cloud_observations: u64,
}

/// Equation-1 latency breakdown for one split point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    pub split: usize,
    pub edge: Duration,
    pub transfer: Duration,
    pub cloud: Duration,
}

impl LatencyBreakdown {
    pub fn total(&self) -> Duration {
        self.edge + self.transfer + self.cloud
    }
}

/// Full per-layer profile of a model on an edge/cloud pair.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub model: String,
    pub input_bytes: usize,
    pub layers: Vec<LayerProfile>,
}

impl ModelProfile {
    /// Analytic profile from manifest FLOPs: `time = flops / gflops`.
    /// Preserves the *relative* per-layer weight that drives split motion.
    pub fn analytic(manifest: &ModelManifest, edge_gflops: f64, cloud_gflops: f64) -> Self {
        let layers = manifest
            .layers
            .iter()
            .map(|l| LayerProfile {
                index: l.index,
                name: l.name.clone(),
                kind: l.kind.clone(),
                edge_time: Duration::from_secs_f64(l.flops as f64 / (edge_gflops * 1e9)),
                cloud_time: Duration::from_secs_f64(l.flops as f64 / (cloud_gflops * 1e9)),
                output_bytes: l.output_bytes,
                ..Default::default()
            })
            .collect();
        ModelProfile {
            model: manifest.name.clone(),
            input_bytes: manifest.input_shape.iter().product::<usize>() * 4,
            layers,
        }
    }

    /// Raw f32 bytes crossing the network at split `k` (`k = 0` ships the
    /// input frame, `k = N` ships the final output).
    pub fn cut_bytes(&self, split: usize) -> usize {
        assert!(split <= self.layers.len());
        if split == 0 {
            self.input_bytes
        } else {
            self.layers[split - 1].output_bytes
        }
    }

    /// Equation 1 for split `k`: edge runs `[0,k)`, transfer of the split
    /// tensor, cloud runs `[k,N)`. CPU availability divides edge speed.
    /// Transfer is costed at the raw (fp32) payload.
    pub fn breakdown(
        &self,
        split: usize,
        bandwidth_mbps: f64,
        latency: Duration,
        edge_cpu_avail: f64,
    ) -> LatencyBreakdown {
        self.breakdown_coded(split, bandwidth_mbps, latency, edge_cpu_avail, TransferCodec::Fp32)
    }

    /// [`Self::breakdown`] with the transfer term costed at the codec's
    /// *encoded* bytes-per-cut. The codec must be visible here, not bolted
    /// on after planning: quartering the payload moves the Equation-1
    /// optimum (usually to an earlier split, because cheap transfers favour
    /// offloading compute to the faster cloud).
    pub fn breakdown_coded(
        &self,
        split: usize,
        bandwidth_mbps: f64,
        latency: Duration,
        edge_cpu_avail: f64,
        codec: TransferCodec,
    ) -> LatencyBreakdown {
        assert!(split <= self.layers.len());
        let edge: Duration = self.layers[..split]
            .iter()
            .map(|l| l.edge_time)
            .sum::<Duration>()
            .mul_f64(1.0 / edge_cpu_avail.max(1e-6));
        let cloud: Duration = self.layers[split..].iter().map(|l| l.cloud_time).sum();
        let bytes = codec.encoded_bytes(self.cut_bytes(split));
        LatencyBreakdown {
            split,
            edge,
            transfer: transfer_time(bytes, bandwidth_mbps, latency),
            cloud,
        }
    }

    /// The optimal split point under the given conditions (argmin of Eq 1).
    pub fn optimal_split(&self, bandwidth_mbps: f64, latency: Duration, edge_cpu: f64) -> usize {
        self.optimal_split_coded(bandwidth_mbps, latency, edge_cpu, TransferCodec::Fp32)
    }

    /// Argmin of Equation 1 with codec-encoded transfer bytes.
    pub fn optimal_split_coded(
        &self,
        bandwidth_mbps: f64,
        latency: Duration,
        edge_cpu: f64,
        codec: TransferCodec,
    ) -> usize {
        (0..=self.layers.len())
            .min_by_key(|&k| {
                self.breakdown_coded(k, bandwidth_mbps, latency, edge_cpu, codec)
                    .total()
            })
            .unwrap()
    }

    /// Fold one frame's observed per-layer timings back into the profile:
    /// `edge_per_layer`/`cloud_per_layer` straight from an
    /// [`InferenceReport`] taken at split `split` (edge entry j is manifest
    /// layer j; cloud entry j is layer `split + j`). Each covered layer's
    /// estimate moves by an exponentially-weighted moving average with the
    /// `NEUKONFIG_PROFILE_ALPHA` weight (default 0.3): low alpha distrusts
    /// a single noisy frame, repeated observations still converge on the
    /// measured value. Per-layer observation counts are bumped alongside.
    /// Entries past the profile tail are ignored. Returns how many layer
    /// estimates were updated.
    ///
    /// [`InferenceReport`]: crate::coordinator::InferenceReport
    pub fn apply_observation(
        &mut self,
        split: usize,
        edge_per_layer: &[Duration],
        cloud_per_layer: &[Duration],
    ) -> usize {
        self.apply_observation_alpha(split, edge_per_layer, cloud_per_layer, default_profile_alpha())
    }

    /// [`Self::apply_observation`] with an explicit EWMA weight (clamped to
    /// (0, 1]; 0.5 reproduces the historical midpoint blend, 1.0 trusts the
    /// newest frame entirely).
    pub fn apply_observation_alpha(
        &mut self,
        split: usize,
        edge_per_layer: &[Duration],
        cloud_per_layer: &[Duration],
        alpha: f64,
    ) -> usize {
        let alpha = alpha.clamp(f64::MIN_POSITIVE, 1.0);
        let mut updated = 0;
        for (j, d) in edge_per_layer.iter().enumerate().take(split.min(self.layers.len())) {
            let l = &mut self.layers[j];
            l.edge_time = ewma(l.edge_time, *d, alpha);
            l.edge_observations += 1;
            updated += 1;
        }
        for (j, d) in cloud_per_layer.iter().enumerate() {
            let Some(layer) = self.layers.get_mut(split + j) else { break };
            layer.cloud_time = ewma(layer.cloud_time, *d, alpha);
            layer.cloud_observations += 1;
            updated += 1;
        }
        updated
    }

    /// All split breakdowns — the rows of Fig 2 / Fig 3.
    pub fn sweep(
        &self,
        bandwidth_mbps: f64,
        latency: Duration,
        edge_cpu: f64,
    ) -> Vec<LatencyBreakdown> {
        (0..=self.layers.len())
            .map(|k| self.breakdown(k, bandwidth_mbps, latency, edge_cpu))
            .collect()
    }
}

/// `old * (1 - alpha) + observed * alpha`.
fn ewma(old: Duration, observed: Duration, alpha: f64) -> Duration {
    old.mul_f64(1.0 - alpha) + observed.mul_f64(alpha)
}

/// Default EWMA weight for profile updates.
pub const DEFAULT_PROFILE_ALPHA: f64 = 0.3;

/// EWMA weight from `NEUKONFIG_PROFILE_ALPHA` (must be a finite value in
/// (0, 1]; anything else falls back to [`DEFAULT_PROFILE_ALPHA`]).
pub fn default_profile_alpha() -> f64 {
    parse_profile_alpha(std::env::var("NEUKONFIG_PROFILE_ALPHA").ok().as_deref())
}

fn parse_profile_alpha(raw: Option<&str>) -> f64 {
    raw.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|a| a.is_finite() && *a > 0.0 && *a <= 1.0)
        .unwrap_or(DEFAULT_PROFILE_ALPHA)
}

/// Calibrated analytic profile for a known model.
///
/// The width-scaled models have ~w^2 less compute but only ~w smaller
/// activations than the paper's full-size networks, so the GFLOPS figure
/// that restores the paper's compute-vs-transfer balance (where the
/// optimal split moves with bandwidth, Figs 2/3) differs per model. These
/// values were calibrated against the exported manifests (DESIGN.md
/// §Substitutions).
pub fn default_analytic(manifest: &ModelManifest) -> ModelProfile {
    let (edge_gflops, cloud_gflops) = match manifest.name.as_str() {
        "vgg19" => (4.0, 8.0),
        "mobilenetv2" => (1.5, 3.0),
        _ => (2.0, 4.0),
    };
    ModelProfile::analytic(manifest, edge_gflops, cloud_gflops)
}

/// Measure a real per-layer profile by executing every unit `reps` times on
/// both domains (real-time benchmarking approach of §III "Identify new
/// metadata", ref [6] Scission).
pub fn measure(
    manifest: &ModelManifest,
    weights: &WeightStore,
    edge: Arc<Domain>,
    cloud: Arc<Domain>,
    reps: usize,
) -> Result<ModelProfile> {
    let n = manifest.num_layers();
    let edge_chain = ChainExecutor::build(edge.clone(), manifest, 0..n, weights)?;
    let cloud_chain = ChainExecutor::build(cloud.clone(), manifest, 0..n, weights)?;

    let numel: usize = manifest.input_shape.iter().product();
    let input = literal_from_f32(&manifest.input_shape, &vec![0.5f32; numel])?;

    let mut layers = Vec::with_capacity(n);
    let mut cur = input;
    for i in 0..n {
        // Warmup once, then take the minimum of `reps` runs (least-noise
        // estimator for compute-bound kernels).
        let e = edge_chain.layer(i);
        let c = cloud_chain.layer(i);
        e.run(&cur)?;
        c.run(&cur)?;
        let mut edge_best = Duration::MAX;
        let mut cloud_best = Duration::MAX;
        for _ in 0..reps.max(1) {
            let t0 = Stopwatch::start();
            e.run(&cur)?;
            edge_best = edge_best.min(t0.elapsed());
            let t1 = Stopwatch::start();
            c.run(&cur)?;
            cloud_best = cloud_best.min(t1.elapsed());
        }
        let lm = &manifest.layers[i];
        layers.push(LayerProfile {
            index: i,
            name: lm.name.clone(),
            kind: lm.kind.clone(),
            // Apply the domains' speed factors (cloud is 2x the edge in the
            // paper's testbed; both executables actually ran on this host).
            edge_time: edge_best.mul_f64(1.0 / edge.cpu_scale().max(1e-6)),
            cloud_time: cloud_best.mul_f64(1.0 / cloud.cpu_scale().max(1e-6)),
            output_bytes: lm.output_bytes,
            ..Default::default()
        });
        cur = e.run(&cur)?;
    }
    Ok(ModelProfile {
        model: manifest.name.clone(),
        input_bytes: manifest.input_shape.iter().product::<usize>() * 4,
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic profile shaped like a CNN: early layers are compute-
    /// heavy with large outputs; later layers cheap with small outputs.
    fn cnn_like() -> ModelProfile {
        let mut layers = Vec::new();
        for i in 0..10 {
            let ms = if i < 6 { 30 } else { 5 };
            let out = if i < 6 { 1_000_000 >> i } else { 4_000 };
            layers.push(LayerProfile {
                index: i,
                name: format!("l{i}"),
                kind: "conv".into(),
                edge_time: Duration::from_millis(ms),
                cloud_time: Duration::from_millis(ms / 5),
                output_bytes: out,
                ..Default::default()
            });
        }
        ModelProfile { model: "toy".into(), input_bytes: 2_000_000, layers }
    }

    #[test]
    fn breakdown_total_is_sum() {
        let p = cnn_like();
        let b = p.breakdown(3, 20.0, Duration::from_millis(20), 1.0);
        assert_eq!(b.total(), b.edge + b.transfer + b.cloud);
        assert_eq!(b.split, 3);
    }

    #[test]
    fn split_zero_ships_raw_input() {
        let p = cnn_like();
        let b = p.breakdown(0, 20.0, Duration::from_millis(20), 1.0);
        assert_eq!(b.edge, Duration::ZERO);
        let expect = transfer_time(2_000_000, 20.0, Duration::from_millis(20));
        assert_eq!(b.transfer, expect);
    }

    #[test]
    fn optimal_split_moves_with_bandwidth() {
        // The paper's core observation (Fig 2/3): dropping bandwidth pushes
        // the optimal split deeper into the network (smaller tensors).
        let p = cnn_like();
        let fast = p.optimal_split(1000.0, Duration::from_millis(1), 1.0);
        let slow = p.optimal_split(1.0, Duration::from_millis(1), 1.0);
        assert!(
            slow >= fast,
            "slow-network split {slow} should be >= fast-network split {fast}"
        );
        assert!(slow >= 6, "slow network should cross the size cliff");
    }

    #[test]
    fn cpu_stress_shifts_work_to_cloud() {
        let p = cnn_like();
        let unstressed = p.breakdown(6, 20.0, Duration::from_millis(20), 1.0);
        let stressed = p.breakdown(6, 20.0, Duration::from_millis(20), 0.25);
        assert_eq!(stressed.edge, unstressed.edge.mul_f64(4.0));
        // And the optimum prefers shallower edge splits under stress.
        let s_opt = p.optimal_split(20.0, Duration::from_millis(20), 0.05);
        let u_opt = p.optimal_split(20.0, Duration::from_millis(20), 1.0);
        assert!(s_opt <= u_opt);
    }

    #[test]
    fn sweep_covers_all_splits() {
        let p = cnn_like();
        let rows = p.sweep(20.0, Duration::from_millis(20), 1.0);
        assert_eq!(rows.len(), 11);
        let opt = p.optimal_split(20.0, Duration::from_millis(20), 1.0);
        let min = rows.iter().min_by_key(|b| b.total()).unwrap();
        assert_eq!(min.split, opt);
    }

    #[test]
    fn observation_blends_toward_measured() {
        let mut p = cnn_like();
        // Split 2: edge covers layers 0..2, cloud covers 2..10. Observe the
        // edge twice as slow and the first cloud layer twice as fast.
        let edge_obs = vec![Duration::from_millis(60), Duration::from_millis(60)];
        let cloud_obs = vec![Duration::from_millis(3)];
        let updated = p.apply_observation(2, &edge_obs, &cloud_obs);
        assert_eq!(updated, 3);
        // EWMA at the default alpha 0.3: 30 * 0.7 + 60 * 0.3 = 39 ms (tiny
        // tolerance for Duration::mul_f64 nanosecond rounding).
        let close = |got: Duration, want: Duration| {
            got.max(want) - got.min(want) < Duration::from_nanos(100)
        };
        assert!(close(p.layers[0].edge_time, Duration::from_millis(39)));
        assert!(close(p.layers[1].edge_time, Duration::from_millis(39)));
        // cloud_time prior for layer 2 is 6 ms: 6 * 0.7 + 3 * 0.3 = 5.1 ms.
        assert!(close(p.layers[2].cloud_time, Duration::from_micros(5100)));
        // Observation counters track covered layers only.
        assert_eq!(p.layers[0].edge_observations, 1);
        assert_eq!(p.layers[1].edge_observations, 1);
        assert_eq!(p.layers[2].cloud_observations, 1);
        assert_eq!(p.layers[2].edge_observations, 0);
        assert_eq!(p.layers[3].cloud_observations, 0);
        // Untouched layers keep their priors.
        assert_eq!(p.layers[3].cloud_time, Duration::from_millis(6));
        // Converges on the measured value with repetition: the 30 ms gap
        // decays by 0.7 per frame, 30 ms * 0.7^21 < 100 us.
        for _ in 0..20 {
            p.apply_observation(2, &edge_obs, &cloud_obs);
        }
        let got = p.layers[0].edge_time;
        let want = Duration::from_millis(60);
        let err = got.max(want) - got.min(want);
        assert!(err < Duration::from_micros(100), "did not converge: {err:?}");
        assert_eq!(p.layers[0].edge_observations, 21);
    }

    #[test]
    fn observation_alpha_half_is_the_midpoint_blend() {
        let mut p = cnn_like();
        let edge_obs = vec![Duration::from_millis(60)];
        p.apply_observation_alpha(1, &edge_obs, &[], 0.5);
        assert_eq!(p.layers[0].edge_time, Duration::from_millis(45));
        // alpha 1.0 adopts the observation outright.
        p.apply_observation_alpha(1, &edge_obs, &[], 1.0);
        assert_eq!(p.layers[0].edge_time, Duration::from_millis(60));
    }

    #[test]
    fn profile_alpha_parsing() {
        assert_eq!(parse_profile_alpha(None), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("")), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("nope")), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("0")), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("-0.3")), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("1.5")), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("inf")), DEFAULT_PROFILE_ALPHA);
        assert_eq!(parse_profile_alpha(Some("0.5")), 0.5);
        assert_eq!(parse_profile_alpha(Some(" 1 ")), 1.0);
    }

    #[test]
    fn coded_breakdown_shrinks_only_the_transfer_term() {
        let p = cnn_like();
        let raw = p.breakdown(3, 20.0, Duration::from_millis(20), 1.0);
        let coded =
            p.breakdown_coded(3, 20.0, Duration::from_millis(20), 1.0, TransferCodec::Int8);
        assert_eq!(coded.edge, raw.edge);
        assert_eq!(coded.cloud, raw.cloud);
        assert!(coded.transfer < raw.transfer);
        let expect = transfer_time(
            TransferCodec::Int8.encoded_bytes(p.cut_bytes(3)),
            20.0,
            Duration::from_millis(20),
        );
        assert_eq!(coded.transfer, expect);
        // Fp32 is the identity codec.
        let fp32 =
            p.breakdown_coded(3, 20.0, Duration::from_millis(20), 1.0, TransferCodec::Fp32);
        assert_eq!(fp32, raw);
    }

    #[test]
    fn int8_codec_moves_the_optimal_split_earlier() {
        // Quartered transfers make shipping out early (to the 5x faster
        // cloud) cheap: the Equation-1 optimum moves to an earlier split.
        let p = cnn_like();
        let bw = 20.0;
        let lat = Duration::from_millis(20);
        let fp32 = p.optimal_split(bw, lat, 1.0);
        let int8 = p.optimal_split_coded(bw, lat, 1.0, TransferCodec::Int8);
        assert_ne!(int8, fp32, "codec must be visible to the planner");
        assert!(int8 < fp32, "int8 optimum {int8} vs fp32 optimum {fp32}");
    }

    #[test]
    fn observation_ignores_overlong_tails() {
        let mut p = cnn_like();
        // 12 edge entries against a 10-layer profile at split 10, and cloud
        // entries starting past the tail: out-of-range entries are dropped.
        let long = vec![Duration::from_millis(1); 12];
        assert_eq!(p.apply_observation(10, &long, &long), 10);
        assert_eq!(p.apply_observation(10, &[], &long), 0);
    }

    #[test]
    fn analytic_profile_scales_with_gflops() {
        use crate::models::{LayerManifest, ModelManifest};
        use std::path::PathBuf;
        let manifest = ModelManifest {
            name: "m".into(),
            input_shape: vec![1, 4, 4, 3],
            weights_bytes: 0,
            total_flops: 2_000_000_000,
            layers: vec![LayerManifest {
                index: 0,
                name: "l0".into(),
                kind: "conv".into(),
                hlo: "x".into(),
                input_shape: vec![1, 4, 4, 3],
                output_shape: vec![1, 4, 4, 3],
                output_bytes: 192,
                flops: 2_000_000_000,
                params: vec![],
            }],
            fused: vec![],
            dir: PathBuf::new(),
        };
        let p = ModelProfile::analytic(&manifest, 2.0, 4.0);
        assert_eq!(p.layers[0].edge_time, Duration::from_secs(1));
        assert_eq!(p.layers[0].cloud_time, Duration::from_millis(500));
    }
}
