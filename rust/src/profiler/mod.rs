//! Per-layer profiler and the Equation-1 latency model.
//!
//! §II of the paper profiles each layer's compute time on the edge and the
//! cloud plus the size of the tensor crossing each split point, then picks
//! the split minimising `T_inf = T_e + T_t + T_c` (Equation 1). This module
//! does the same against the real PJRT executables ([`measure`]) or from
//! manifest FLOPs when no artifacts are available ([`ModelProfile::analytic`],
//! used by pure-logic tests and fast sweeps).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::models::ModelManifest;
use crate::netsim::transfer_time;
use crate::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};

/// Profile of one partition unit.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub index: usize,
    pub name: String,
    pub kind: String,
    pub edge_time: Duration,
    pub cloud_time: Duration,
    pub output_bytes: usize,
}

/// Equation-1 latency breakdown for one split point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    pub split: usize,
    pub edge: Duration,
    pub transfer: Duration,
    pub cloud: Duration,
}

impl LatencyBreakdown {
    pub fn total(&self) -> Duration {
        self.edge + self.transfer + self.cloud
    }
}

/// Full per-layer profile of a model on an edge/cloud pair.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub model: String,
    pub input_bytes: usize,
    pub layers: Vec<LayerProfile>,
}

impl ModelProfile {
    /// Analytic profile from manifest FLOPs: `time = flops / gflops`.
    /// Preserves the *relative* per-layer weight that drives split motion.
    pub fn analytic(manifest: &ModelManifest, edge_gflops: f64, cloud_gflops: f64) -> Self {
        let layers = manifest
            .layers
            .iter()
            .map(|l| LayerProfile {
                index: l.index,
                name: l.name.clone(),
                kind: l.kind.clone(),
                edge_time: Duration::from_secs_f64(l.flops as f64 / (edge_gflops * 1e9)),
                cloud_time: Duration::from_secs_f64(l.flops as f64 / (cloud_gflops * 1e9)),
                output_bytes: l.output_bytes,
            })
            .collect();
        ModelProfile {
            model: manifest.name.clone(),
            input_bytes: manifest.input_shape.iter().product::<usize>() * 4,
            layers,
        }
    }

    /// Equation 1 for split `k`: edge runs `[0,k)`, transfer of the split
    /// tensor, cloud runs `[k,N)`. CPU availability divides edge speed.
    pub fn breakdown(
        &self,
        split: usize,
        bandwidth_mbps: f64,
        latency: Duration,
        edge_cpu_avail: f64,
    ) -> LatencyBreakdown {
        assert!(split <= self.layers.len());
        let edge: Duration = self.layers[..split]
            .iter()
            .map(|l| l.edge_time)
            .sum::<Duration>()
            .mul_f64(1.0 / edge_cpu_avail.max(1e-6));
        let cloud: Duration = self.layers[split..].iter().map(|l| l.cloud_time).sum();
        let bytes = if split == 0 {
            self.input_bytes
        } else {
            self.layers[split - 1].output_bytes
        };
        LatencyBreakdown {
            split,
            edge,
            transfer: transfer_time(bytes, bandwidth_mbps, latency),
            cloud,
        }
    }

    /// The optimal split point under the given conditions (argmin of Eq 1).
    pub fn optimal_split(&self, bandwidth_mbps: f64, latency: Duration, edge_cpu: f64) -> usize {
        (0..=self.layers.len())
            .min_by_key(|&k| self.breakdown(k, bandwidth_mbps, latency, edge_cpu).total())
            .unwrap()
    }

    /// Fold one frame's observed per-layer timings back into the profile:
    /// `edge_per_layer`/`cloud_per_layer` straight from an
    /// [`InferenceReport`] taken at split `split` (edge entry j is manifest
    /// layer j; cloud entry j is layer `split + j`). Each covered layer's
    /// estimate moves to the midpoint of old and observed — an equal-weight
    /// blend, so one noisy frame can't wipe out the analytic prior and
    /// repeated observations converge on the measured value. Entries past
    /// the profile tail are ignored. Returns how many layer estimates were
    /// updated.
    ///
    /// [`InferenceReport`]: crate::coordinator::InferenceReport
    pub fn apply_observation(
        &mut self,
        split: usize,
        edge_per_layer: &[Duration],
        cloud_per_layer: &[Duration],
    ) -> usize {
        let mut updated = 0;
        for (j, d) in edge_per_layer.iter().enumerate().take(split.min(self.layers.len())) {
            let t = &mut self.layers[j].edge_time;
            *t = (*t + *d) / 2;
            updated += 1;
        }
        for (j, d) in cloud_per_layer.iter().enumerate() {
            let Some(layer) = self.layers.get_mut(split + j) else { break };
            layer.cloud_time = (layer.cloud_time + *d) / 2;
            updated += 1;
        }
        updated
    }

    /// All split breakdowns — the rows of Fig 2 / Fig 3.
    pub fn sweep(
        &self,
        bandwidth_mbps: f64,
        latency: Duration,
        edge_cpu: f64,
    ) -> Vec<LatencyBreakdown> {
        (0..=self.layers.len())
            .map(|k| self.breakdown(k, bandwidth_mbps, latency, edge_cpu))
            .collect()
    }
}

/// Calibrated analytic profile for a known model.
///
/// The width-scaled models have ~w^2 less compute but only ~w smaller
/// activations than the paper's full-size networks, so the GFLOPS figure
/// that restores the paper's compute-vs-transfer balance (where the
/// optimal split moves with bandwidth, Figs 2/3) differs per model. These
/// values were calibrated against the exported manifests (DESIGN.md
/// §Substitutions).
pub fn default_analytic(manifest: &ModelManifest) -> ModelProfile {
    let (edge_gflops, cloud_gflops) = match manifest.name.as_str() {
        "vgg19" => (4.0, 8.0),
        "mobilenetv2" => (1.5, 3.0),
        _ => (2.0, 4.0),
    };
    ModelProfile::analytic(manifest, edge_gflops, cloud_gflops)
}

/// Measure a real per-layer profile by executing every unit `reps` times on
/// both domains (real-time benchmarking approach of §III "Identify new
/// metadata", ref [6] Scission).
pub fn measure(
    manifest: &ModelManifest,
    weights: &WeightStore,
    edge: Arc<Domain>,
    cloud: Arc<Domain>,
    reps: usize,
) -> Result<ModelProfile> {
    let n = manifest.num_layers();
    let edge_chain = ChainExecutor::build(edge.clone(), manifest, 0..n, weights)?;
    let cloud_chain = ChainExecutor::build(cloud.clone(), manifest, 0..n, weights)?;

    let numel: usize = manifest.input_shape.iter().product();
    let input = literal_from_f32(&manifest.input_shape, &vec![0.5f32; numel])?;

    let mut layers = Vec::with_capacity(n);
    let mut cur = input;
    for i in 0..n {
        // Warmup once, then take the minimum of `reps` runs (least-noise
        // estimator for compute-bound kernels).
        let e = edge_chain.layer(i);
        let c = cloud_chain.layer(i);
        e.run(&cur)?;
        c.run(&cur)?;
        let mut edge_best = Duration::MAX;
        let mut cloud_best = Duration::MAX;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            e.run(&cur)?;
            edge_best = edge_best.min(t0.elapsed());
            let t1 = Instant::now();
            c.run(&cur)?;
            cloud_best = cloud_best.min(t1.elapsed());
        }
        let lm = &manifest.layers[i];
        layers.push(LayerProfile {
            index: i,
            name: lm.name.clone(),
            kind: lm.kind.clone(),
            // Apply the domains' speed factors (cloud is 2x the edge in the
            // paper's testbed; both executables actually ran on this host).
            edge_time: edge_best.mul_f64(1.0 / edge.cpu_scale().max(1e-6)),
            cloud_time: cloud_best.mul_f64(1.0 / cloud.cpu_scale().max(1e-6)),
            output_bytes: lm.output_bytes,
        });
        cur = e.run(&cur)?;
    }
    Ok(ModelProfile {
        model: manifest.name.clone(),
        input_bytes: manifest.input_shape.iter().product::<usize>() * 4,
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic profile shaped like a CNN: early layers are compute-
    /// heavy with large outputs; later layers cheap with small outputs.
    fn cnn_like() -> ModelProfile {
        let mut layers = Vec::new();
        for i in 0..10 {
            let ms = if i < 6 { 30 } else { 5 };
            let out = if i < 6 { 1_000_000 >> i } else { 4_000 };
            layers.push(LayerProfile {
                index: i,
                name: format!("l{i}"),
                kind: "conv".into(),
                edge_time: Duration::from_millis(ms),
                cloud_time: Duration::from_millis(ms / 5),
                output_bytes: out,
            });
        }
        ModelProfile { model: "toy".into(), input_bytes: 2_000_000, layers }
    }

    #[test]
    fn breakdown_total_is_sum() {
        let p = cnn_like();
        let b = p.breakdown(3, 20.0, Duration::from_millis(20), 1.0);
        assert_eq!(b.total(), b.edge + b.transfer + b.cloud);
        assert_eq!(b.split, 3);
    }

    #[test]
    fn split_zero_ships_raw_input() {
        let p = cnn_like();
        let b = p.breakdown(0, 20.0, Duration::from_millis(20), 1.0);
        assert_eq!(b.edge, Duration::ZERO);
        let expect = transfer_time(2_000_000, 20.0, Duration::from_millis(20));
        assert_eq!(b.transfer, expect);
    }

    #[test]
    fn optimal_split_moves_with_bandwidth() {
        // The paper's core observation (Fig 2/3): dropping bandwidth pushes
        // the optimal split deeper into the network (smaller tensors).
        let p = cnn_like();
        let fast = p.optimal_split(1000.0, Duration::from_millis(1), 1.0);
        let slow = p.optimal_split(1.0, Duration::from_millis(1), 1.0);
        assert!(
            slow >= fast,
            "slow-network split {slow} should be >= fast-network split {fast}"
        );
        assert!(slow >= 6, "slow network should cross the size cliff");
    }

    #[test]
    fn cpu_stress_shifts_work_to_cloud() {
        let p = cnn_like();
        let unstressed = p.breakdown(6, 20.0, Duration::from_millis(20), 1.0);
        let stressed = p.breakdown(6, 20.0, Duration::from_millis(20), 0.25);
        assert_eq!(stressed.edge, unstressed.edge.mul_f64(4.0));
        // And the optimum prefers shallower edge splits under stress.
        let s_opt = p.optimal_split(20.0, Duration::from_millis(20), 0.05);
        let u_opt = p.optimal_split(20.0, Duration::from_millis(20), 1.0);
        assert!(s_opt <= u_opt);
    }

    #[test]
    fn sweep_covers_all_splits() {
        let p = cnn_like();
        let rows = p.sweep(20.0, Duration::from_millis(20), 1.0);
        assert_eq!(rows.len(), 11);
        let opt = p.optimal_split(20.0, Duration::from_millis(20), 1.0);
        let min = rows.iter().min_by_key(|b| b.total()).unwrap();
        assert_eq!(min.split, opt);
    }

    #[test]
    fn observation_blends_toward_measured() {
        let mut p = cnn_like();
        // Split 2: edge covers layers 0..2, cloud covers 2..10. Observe the
        // edge twice as slow and the first cloud layer twice as fast.
        let edge_obs = vec![Duration::from_millis(60), Duration::from_millis(60)];
        let cloud_obs = vec![Duration::from_millis(3)];
        let updated = p.apply_observation(2, &edge_obs, &cloud_obs);
        assert_eq!(updated, 3);
        // Midpoint of 30 ms prior and 60 ms observed.
        assert_eq!(p.layers[0].edge_time, Duration::from_millis(45));
        assert_eq!(p.layers[1].edge_time, Duration::from_millis(45));
        // cloud_time prior for layer 2 is 30/5 = 6 ms; midpoint with 3 ms.
        assert_eq!(p.layers[2].cloud_time, Duration::from_micros(4500));
        // Untouched layers keep their priors.
        assert_eq!(p.layers[3].cloud_time, Duration::from_millis(6));
        // Converges on the measured value with repetition.
        for _ in 0..20 {
            p.apply_observation(2, &edge_obs, &cloud_obs);
        }
        let got = p.layers[0].edge_time;
        let want = Duration::from_millis(60);
        let err = got.max(want) - got.min(want);
        assert!(err < Duration::from_micros(100), "did not converge: {err:?}");
    }

    #[test]
    fn observation_ignores_overlong_tails() {
        let mut p = cnn_like();
        // 12 edge entries against a 10-layer profile at split 10, and cloud
        // entries starting past the tail: out-of-range entries are dropped.
        let long = vec![Duration::from_millis(1); 12];
        assert_eq!(p.apply_observation(10, &long, &long), 10);
        assert_eq!(p.apply_observation(10, &[], &long), 0);
    }

    #[test]
    fn analytic_profile_scales_with_gflops() {
        use crate::models::{LayerManifest, ModelManifest};
        use std::path::PathBuf;
        let manifest = ModelManifest {
            name: "m".into(),
            input_shape: vec![1, 4, 4, 3],
            weights_bytes: 0,
            total_flops: 2_000_000_000,
            layers: vec![LayerManifest {
                index: 0,
                name: "l0".into(),
                kind: "conv".into(),
                hlo: "x".into(),
                input_shape: vec![1, 4, 4, 3],
                output_shape: vec![1, 4, 4, 3],
                output_bytes: 192,
                flops: 2_000_000_000,
                params: vec![],
            }],
            fused: vec![],
            dir: PathBuf::new(),
        };
        let p = ModelProfile::analytic(&manifest, 2.0, 4.0);
        assert_eq!(p.layers[0].edge_time, Duration::from_secs(1));
        assert_eq!(p.layers[0].cloud_time, Duration::from_millis(500));
    }
}
