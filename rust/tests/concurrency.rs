//! Concurrency coverage for the perf layer: parallel bring-up equivalence,
//! weight-buffer cache behaviour across repartitions, overlapped frame
//! execution, and state-machine safety under racing switches.
//!
//! Artifact-backed tests skip (like the other integration suites) when
//! `make artifacts` has not run.

use std::sync::Arc;
use std::time::Duration;

use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{PipelinedRunner, Placement, PipelineState};
use neukonfig::device::FrameSource;
use neukonfig::models::{default_artifacts_dir, ArtifactIndex};
use neukonfig::runtime::{literal_from_f32, BuildOptions, ChainExecutor, Domain, WeightStore};

const MODEL: &str = "mobilenetv2";

fn artifacts() -> Option<ArtifactIndex> {
    ArtifactIndex::load(default_artifacts_dir()).ok()
}

fn setup() -> Option<ExperimentSetup> {
    ExperimentSetup::load().ok()
}

/// Parallel bring-up must be a pure wall-clock optimisation: same chain,
/// same outputs, same bookkeeping totals as the serial path.
#[test]
fn parallel_build_matches_serial() {
    let Some(index) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model(MODEL).unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let n = manifest.num_layers();

    let serial = ChainExecutor::build_with(
        Domain::new("serial", 1.0).unwrap(),
        &manifest,
        0..n,
        &weights,
        BuildOptions::serial(true),
    )
    .unwrap();
    let parallel = ChainExecutor::build_with(
        Domain::new("parallel", 1.0).unwrap(),
        &manifest,
        0..n,
        &weights,
        BuildOptions::parallel(true),
    )
    .unwrap();

    assert_eq!(serial.build_stats.num_layers, n);
    assert_eq!(parallel.build_stats.num_layers, n);
    // Fresh domains: every layer is a cache miss on both paths.
    assert_eq!(parallel.build_stats.weight_cache_misses as usize, n);
    assert_eq!(parallel.build_stats.weight_cache_hits, 0);

    let numel: usize = manifest.input_shape.iter().product();
    let input = literal_from_f32(&manifest.input_shape, &vec![0.3f32; numel]).unwrap();
    let a = serial.run_raw(&input).unwrap().to_vec::<f32>().unwrap();
    let b = parallel.run_raw(&input).unwrap().to_vec::<f32>().unwrap();
    assert_eq!(a, b, "parallel bring-up changed the chain's outputs");
}

/// After `warm_executables`, a repartition to any split must hit the
/// weight-buffer cache on every layer — near-zero `weights_upload`.
#[test]
fn weight_cache_hits_across_repartition() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();

    env.warm_executables().unwrap();
    assert_eq!(env.edge.weight_cache_len(), n);
    assert_eq!(env.cloud.weight_cache_len(), n);
    env.edge.reset_weight_cache_stats();
    env.cloud.reset_weight_cache_stats();

    // "Repartition" to an arbitrary split: all n layer stagings must hit.
    let p = env.build_pipeline(n / 3, Placement::NewContainers).unwrap();
    assert_eq!(p.init_stats.weight_cache_misses, 0, "warm cache must not miss");
    assert_eq!(p.init_stats.weight_cache_hits as usize, n);
    // Cache hits are hashmap lookups, not uploads.
    assert!(
        p.init_stats.weights_upload_cpu < Duration::from_millis(50),
        "cached staging should be ~zero, got {:?}",
        p.init_stats.weights_upload_cpu
    );

    // The naive-baseline invalidation path starts over from cold.
    env.edge.clear_cache();
    env.cloud.clear_cache();
    assert_eq!(env.edge.weight_cache_len(), 0);
    assert_eq!(env.cloud.weight_cache_len(), 0);
    env.edge.reset_weight_cache_stats();
    env.cloud.reset_weight_cache_stats();
    let p2 = env.build_pipeline(n / 2, Placement::NewContainers).unwrap();
    assert_eq!(p2.init_stats.weight_cache_hits, 0);
    assert_eq!(p2.init_stats.weight_cache_misses as usize, n);
}

/// The overlapped runner must preserve frame order and produce outputs
/// identical to sequential `Pipeline::infer`.
#[test]
fn pipelined_runner_matches_sequential() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let p = env.build_pipeline(n / 2, Placement::NewContainers).unwrap();
    p.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 11);
    let frames: Vec<_> = (0..5)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();

    let sequential: Vec<Vec<f32>> = frames
        .iter()
        .map(|f| p.infer(f).unwrap().output.to_vec::<f32>().unwrap())
        .collect();

    for depth in [1, 2, 4] {
        let reports = PipelinedRunner::new(depth).run(&p, &frames).unwrap();
        assert_eq!(reports.len(), frames.len());
        for (i, (want, rep)) in sequential.iter().zip(&reports).enumerate() {
            assert_eq!(
                want,
                &rep.output.to_vec::<f32>().unwrap(),
                "depth {depth}: frame {i} out of order or corrupted"
            );
            assert!(rep.t_transfer >= env.cfg.network.latency);
            assert!(rep.t_edge > Duration::ZERO);
            assert!(rep.t_cloud > Duration::ZERO);
        }
    }
}

/// The runner honours the same traffic gate as `Pipeline::infer`.
#[test]
fn pipelined_runner_rejects_non_serving_pipeline() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let p = env.build_pipeline(2, Placement::NewContainers).unwrap();
    // Still Initialising — not serving.
    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 1);
    let frames = vec![env.frame_literal(&cam.frame(0)).unwrap()];
    assert!(PipelinedRunner::default().run(&p, &frames).is_err());
}

/// Racing activations: exactly one of N concurrent `transition(Active)`
/// calls may win; the rest must be rejected as illegal (Active -> Active
/// is not a legal edge).
#[test]
fn concurrent_activation_has_single_winner() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let p = Arc::new(env.build_pipeline(2, Placement::NewContainers).unwrap());
    p.transition(PipelineState::Standby).unwrap();

    let threads = 8;
    let barrier = Arc::new(std::sync::Barrier::new(threads));
    let wins: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let p = p.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    barrier.wait();
                    p.transition(PipelineState::Active).is_ok() as usize
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    assert_eq!(wins, 1, "exactly one racer may activate the pipeline");
    assert_eq!(p.state(), PipelineState::Active);
}

/// Depth is clamped to at least one in-flight frame.
#[test]
fn runner_depth_floor() {
    assert_eq!(PipelinedRunner::new(0).depth, 1);
    assert_eq!(PipelinedRunner::new(3).depth, 3);
    assert!(PipelinedRunner::default().depth >= 1);
}
