//! Byte-budgeted weight-cache eviction: the memory-vs-downtime knob.
//!
//! These tests run without model artifacts — synthetic layer manifests
//! over an in-memory `WeightStore` stage real PJRT device buffers through
//! a real `Domain`, so the policy under test is exactly the production
//! path (`Domain::layer_weight_buffers`).

use std::sync::Arc;

use neukonfig::models::{LayerManifest, ParamEntry};
use neukonfig::runtime::{Domain, WeightStore};

/// One synthetic layer: a single `[floats]`-shaped param at `offset`
/// floats into the blob. Staged size = 4 * floats bytes.
fn layer(index: usize, offset_floats: usize, floats: usize) -> LayerManifest {
    LayerManifest {
        index,
        name: format!("syn{index}"),
        kind: "conv".into(),
        hlo: "unused".into(),
        input_shape: vec![1],
        output_shape: vec![1],
        output_bytes: 4,
        flops: 0,
        params: vec![ParamEntry {
            name: format!("w{index}"),
            shape: vec![floats],
            offset_bytes: offset_floats * 4,
            size_bytes: floats * 4,
        }],
    }
}

fn store(total_floats: usize) -> WeightStore {
    WeightStore::from_bytes(vec![0u8; total_floats * 4])
}

fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

fn stage(domain: &Arc<Domain>, ws: &WeightStore, l: &LayerManifest) {
    domain.layer_weight_buffers(ws, l, true).unwrap();
}

#[test]
fn budget_never_exceeded_across_repeated_repartitions() {
    let domain = Domain::new("budgeted", 1.0).unwrap();
    let ws = store(4096);
    // 16 layers x 1 KiB staged each; budget of 4 KiB holds at most 4.
    let layers: Vec<_> = (0..16).map(|i| layer(i, i * 256, 256)).collect();
    domain.set_weight_cache_budget_mb(Some(mb(4096)));

    // "Repartition" sweeps: restage overlapping layer ranges repeatedly.
    for split in [4usize, 9, 2, 14, 7] {
        for l in &layers[..split] {
            stage(&domain, &ws, l);
            assert!(
                domain.weight_cache_bytes() <= 4096,
                "budget exceeded: {} bytes resident",
                domain.weight_cache_bytes()
            );
        }
        assert!(domain.weight_cache_len() <= 4);
    }
    let s = domain.weight_cache_stats();
    assert_eq!(s.bytes, domain.weight_cache_bytes());
    assert_eq!(s.entries as usize, domain.weight_cache_len());
    assert_eq!(s.misses, s.entries + s.evictions, "occupancy must reconcile");
}

#[test]
fn lru_victim_order_is_deterministic() {
    let domain = Domain::new("lru", 1.0).unwrap();
    let ws = store(1024);
    let a = layer(0, 0, 256);
    let b = layer(1, 256, 256);
    let c = layer(2, 512, 256);
    let d = layer(3, 768, 256);
    // Budget holds exactly two 1 KiB entries.
    domain.set_weight_cache_budget_mb(Some(mb(2048)));

    stage(&domain, &ws, &a);
    stage(&domain, &ws, &b);
    // Touch A: B becomes least-recently-used.
    stage(&domain, &ws, &a);
    stage(&domain, &ws, &c);
    assert!(domain.weight_cache_contains(0, "syn0"), "A was just used");
    assert!(!domain.weight_cache_contains(1, "syn1"), "B must be the LRU victim");
    assert!(domain.weight_cache_contains(2, "syn2"));
    // Insert D: A (older than C) goes next.
    stage(&domain, &ws, &d);
    assert!(!domain.weight_cache_contains(0, "syn0"), "A must be evicted next");
    assert!(domain.weight_cache_contains(2, "syn2"));
    assert!(domain.weight_cache_contains(3, "syn3"));

    let s = domain.weight_cache_stats();
    assert_eq!(s.hits, 1, "only the re-touch of A hit");
    assert_eq!(s.misses, 4);
    assert_eq!(s.evictions, 2);
    assert_eq!(s.entries, 2);
    assert_eq!(s.misses, s.entries + s.evictions);
}

#[test]
fn oversize_entry_drains_cache_but_never_lies_about_budget() {
    let domain = Domain::new("oversize", 1.0).unwrap();
    let ws = store(2048);
    domain.set_weight_cache_budget_mb(Some(mb(1024)));
    stage(&domain, &ws, &layer(0, 0, 128)); // 512 B, fits
    assert_eq!(domain.weight_cache_len(), 1);
    // 4 KiB entry can never fit a 1 KiB budget: everything is evicted,
    // including the oversize entry itself.
    stage(&domain, &ws, &layer(1, 0, 1024));
    assert_eq!(domain.weight_cache_len(), 0);
    assert_eq!(domain.weight_cache_bytes(), 0);
    let s = domain.weight_cache_stats();
    assert_eq!(s.evictions, 2);
    assert_eq!(s.misses, s.entries + s.evictions);
}

#[test]
fn shrinking_budget_evicts_immediately() {
    let domain = Domain::new("shrink", 1.0).unwrap();
    let ws = store(1024);
    domain.set_weight_cache_budget_mb(None); // unbounded
    for i in 0..4 {
        stage(&domain, &ws, &layer(i, i * 256, 256));
    }
    assert_eq!(domain.weight_cache_len(), 4);
    assert_eq!(domain.weight_cache_bytes(), 4096);

    // The knob takes effect without waiting for the next staging.
    domain.set_weight_cache_budget_mb(Some(mb(2048)));
    assert_eq!(domain.weight_cache_len(), 2);
    assert!(domain.weight_cache_bytes() <= 2048);
    // Oldest two (0, 1) were the victims.
    assert!(!domain.weight_cache_contains(0, "syn0"));
    assert!(!domain.weight_cache_contains(1, "syn1"));
    assert!(domain.weight_cache_contains(2, "syn2"));
    assert!(domain.weight_cache_contains(3, "syn3"));

    // Lifting the budget stops eviction; nothing comes back by itself.
    domain.set_weight_cache_budget_mb(None);
    assert_eq!(domain.weight_cache_len(), 2);
}

#[test]
fn clear_weight_cache_zeroes_everything_for_pause_resume() {
    let domain = Domain::new("clear", 1.0).unwrap();
    let ws = store(1024);
    domain.set_weight_cache_budget_mb(Some(mb(4096)));
    for i in 0..3 {
        stage(&domain, &ws, &layer(i, i * 256, 256));
    }
    stage(&domain, &ws, &layer(0, 0, 256)); // one hit
    assert!(domain.weight_cache_bytes() > 0);

    domain.clear_weight_cache();
    assert_eq!(domain.weight_cache_len(), 0);
    assert_eq!(domain.weight_cache_bytes(), 0);
    // Counters survive a clear (they describe history, not occupancy)...
    let s = domain.weight_cache_stats();
    assert_eq!(s.hits, 1);
    assert_eq!(s.misses, 3);
    assert_eq!(s.entries, 0);
    assert_eq!(s.bytes, 0);
    // ...and the budget survives too: restaging still enforces it.
    assert_eq!(domain.weight_cache_budget_bytes(), Some(4096));
    // The stats reset zeroes the counters separately.
    domain.reset_weight_cache_stats();
    let s = domain.weight_cache_stats();
    assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
}

#[test]
fn uncached_staging_bypasses_cache_and_counters() {
    let domain = Domain::new("bypass", 1.0).unwrap();
    let ws = store(256);
    domain.set_weight_cache_budget_mb(Some(mb(1024)));
    let l = layer(0, 0, 64);
    let (_, hit) = domain.layer_weight_buffers(&ws, &l, false).unwrap();
    assert!(!hit);
    assert_eq!(domain.weight_cache_len(), 0, "use_cache=false must not populate");
    let s = domain.weight_cache_stats();
    assert_eq!((s.hits, s.misses), (0, 0), "use_cache=false must not count");
}
