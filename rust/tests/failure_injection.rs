//! Failure injection: corrupted or missing artifacts must fail loudly and
//! precisely, never crash or silently mis-serve.

use std::fs;

use neukonfig::models::{default_artifacts_dir, ArtifactIndex, ModelManifest};
use neukonfig::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};

fn with_artifact_copy(model: &str, f: impl FnOnce(&std::path::Path)) {
    let Ok(index) = ArtifactIndex::load(default_artifacts_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let src = index.root.join(model);
    let dst = std::env::temp_dir().join(format!("nk-fault-{}-{}", model, std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(&dst).unwrap();
    for entry in fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    f(&dst);
    let _ = fs::remove_dir_all(&dst);
}

#[test]
fn truncated_weights_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        let wpath = dir.join("weights.bin");
        let blob = fs::read(&wpath).unwrap();
        fs::write(&wpath, &blob[..blob.len() / 2]).unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let err = match WeightStore::load(&manifest) {
            Err(e) => e,
            Ok(_) => panic!("truncated weights accepted"),
        };
        assert!(err.to_string().contains("bytes"), "got: {err}");
    });
}

#[test]
fn corrupt_hlo_fails_at_compile_not_at_run() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::write(dir.join("layer_00.hlo.txt"), "HloModule garbage\nnot hlo").unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("t", 1.0).unwrap();
        let err = match ChainExecutor::build(domain, &manifest, 0..1, &weights) {
            Err(e) => e,
            Ok(_) => panic!("corrupt HLO accepted"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("layer_00"), "error should name the artifact: {msg}");
    });
}

#[test]
fn missing_hlo_file_is_reported() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::remove_file(dir.join("layer_01.hlo.txt")).unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("t", 1.0).unwrap();
        // Layer 0 still builds.
        assert!(ChainExecutor::build(domain.clone(), &manifest, 0..1, &weights).is_ok());
        // Layer 1 does not.
        assert!(ChainExecutor::build(domain, &manifest, 1..2, &weights).is_err());
    });
}

#[test]
fn manifest_with_broken_shapes_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        let mpath = dir.join("manifest.json");
        let text = fs::read_to_string(&mpath).unwrap();
        // Break the chaining: first layer's output shape tampered.
        let broken = text.replacen("\"output_shape\": [", "\"output_shape\": [77, ", 1);
        fs::write(&mpath, broken).unwrap();
        assert!(ModelManifest::load(dir).is_err());
    });
}

#[test]
fn wrong_input_shape_rejected_at_execute() {
    let Ok(index) = ArtifactIndex::load(default_artifacts_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model("mobilenetv2").unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let domain = Domain::new("t", 1.0).unwrap();
    let chain = ChainExecutor::build(domain, &manifest, 0..1, &weights).unwrap();
    // 8x8 frame against a 64x64 executable.
    let bad = literal_from_f32(&[1, 8, 8, 3], &vec![0.0; 192]).unwrap();
    assert!(chain.run_raw(&bad).is_err());
}

#[test]
fn literal_shape_mismatch_rejected() {
    assert!(literal_from_f32(&[2, 2], &[1.0, 2.0, 3.0]).is_err());
}

#[test]
fn garbage_manifest_json_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(ModelManifest::load(dir).is_err());
    });
}
