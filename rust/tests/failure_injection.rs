//! Failure injection: corrupted or missing artifacts must fail loudly and
//! precisely, never crash or silently mis-serve — and the overlapped
//! runner must drain cleanly on mid-burst stage faults, naming the
//! originating stage and frame index, without hangs or partial reports.

use std::fs;
use std::sync::Arc;
use std::time::Duration;

use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{
    arm_degraded_fallback, Pipeline, PipelinedRunner, Placement, PipelineState, PlacementCase,
    RouteOutcome, Router, ScenarioA, ScenarioB,
};
use neukonfig::device::FrameSource;
use neukonfig::models::{default_artifacts_dir, ArtifactIndex, ModelManifest};
use neukonfig::netsim::{FaultPlan, RetryPolicy};
use neukonfig::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};

fn with_artifact_copy(model: &str, f: impl FnOnce(&std::path::Path)) {
    let Ok(index) = ArtifactIndex::load(default_artifacts_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let src = index.root.join(model);
    let dst = std::env::temp_dir().join(format!("nk-fault-{}-{}", model, std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(&dst).unwrap();
    for entry in fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    f(&dst);
    let _ = fs::remove_dir_all(&dst);
}

#[test]
fn truncated_weights_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        let wpath = dir.join("weights.bin");
        let blob = fs::read(&wpath).unwrap();
        fs::write(&wpath, &blob[..blob.len() / 2]).unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let err = match WeightStore::load(&manifest) {
            Err(e) => e,
            Ok(_) => panic!("truncated weights accepted"),
        };
        assert!(err.to_string().contains("bytes"), "got: {err}");
    });
}

#[test]
fn corrupt_hlo_fails_at_compile_not_at_run() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::write(dir.join("layer_00.hlo.txt"), "HloModule garbage\nnot hlo").unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("t", 1.0).unwrap();
        let err = match ChainExecutor::build(domain, &manifest, 0..1, &weights) {
            Err(e) => e,
            Ok(_) => panic!("corrupt HLO accepted"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("layer_00"), "error should name the artifact: {msg}");
    });
}

#[test]
fn missing_hlo_file_is_reported() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::remove_file(dir.join("layer_01.hlo.txt")).unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("t", 1.0).unwrap();
        // Layer 0 still builds.
        assert!(ChainExecutor::build(domain.clone(), &manifest, 0..1, &weights).is_ok());
        // Layer 1 does not.
        assert!(ChainExecutor::build(domain, &manifest, 1..2, &weights).is_err());
    });
}

#[test]
fn manifest_with_broken_shapes_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        let mpath = dir.join("manifest.json");
        let text = fs::read_to_string(&mpath).unwrap();
        // Break the chaining: first layer's output shape tampered.
        let broken = text.replacen("\"output_shape\": [", "\"output_shape\": [77, ", 1);
        fs::write(&mpath, broken).unwrap();
        assert!(ModelManifest::load(dir).is_err());
    });
}

#[test]
fn wrong_input_shape_rejected_at_execute() {
    let Ok(index) = ArtifactIndex::load(default_artifacts_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model("mobilenetv2").unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let domain = Domain::new("t", 1.0).unwrap();
    let chain = ChainExecutor::build(domain, &manifest, 0..1, &weights).unwrap();
    // 8x8 frame against a 64x64 executable.
    let bad = literal_from_f32(&[1, 8, 8, 3], &vec![0.0; 192]).unwrap();
    assert!(chain.run_raw(&bad).is_err());
}

#[test]
fn literal_shape_mismatch_rejected() {
    assert!(literal_from_f32(&[2, 2], &[1.0, 2.0, 3.0]).is_err());
}

#[test]
fn garbage_manifest_json_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(ModelManifest::load(dir).is_err());
    });
}

// ---------------------------------------------------------------------------
// Pipelined-runner fault injection (artifact-gated like the suites above)
// ---------------------------------------------------------------------------

const MODEL: &str = "mobilenetv2";

/// Mid-burst edge-chain fault: frame 2 of 5 has the wrong shape, so the
/// edge stage fails after two good frames. Both stage modes must return a
/// single error naming the edge stage and the frame index — no hang, no
/// partial report set.
#[test]
fn edge_fault_mid_burst_names_stage_and_frame() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let p = env.build_pipeline(n / 2, Placement::NewContainers).unwrap();
    p.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 5);
    let mut frames: Vec<_> = (0..5)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    frames[2] = literal_from_f32(&[1, 8, 8, 3], &vec![0.1; 192]).unwrap();

    for runner in [PipelinedRunner::new(2), PipelinedRunner::two_stage(2)] {
        let err = match runner.run(&p, &frames) {
            Err(e) => e,
            Ok(_) => panic!("bad frame accepted ({:?})", runner.stages),
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("edge stage failed at frame 2"),
            "{:?}: error must name stage + frame, got: {msg}",
            runner.stages
        );
    }
}

/// Cloud-chain fault: at split 0 the (empty) edge chain passes the frame
/// through untouched, so a malformed frame first explodes in the cloud
/// stage. The error must name the cloud stage and frame index.
#[test]
fn cloud_fault_mid_burst_names_stage_and_frame() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let p = env.build_pipeline(0, Placement::NewContainers).unwrap();
    p.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 6);
    let mut frames: Vec<_> = (0..4)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    frames[1] = literal_from_f32(&[1, 8, 8, 3], &vec![0.2; 192]).unwrap();

    for runner in [PipelinedRunner::new(3), PipelinedRunner::two_stage(3)] {
        let err = match runner.run(&p, &frames) {
            Err(e) => e,
            Ok(_) => panic!("bad frame accepted ({:?})", runner.stages),
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("cloud stage failed at frame 1"),
            "{:?}: error must name stage + frame, got: {msg}",
            runner.stages
        );
    }
}

/// Deliberately mismatched chains via the test-support constructor: the
/// edge chain ends at layer 2 but the cloud chain starts at layer 3, so
/// every frame's intermediate has the wrong shape for the cloud stage.
/// The runner must fail at frame 0, cleanly.
#[test]
fn mismatched_chain_boundary_fails_cleanly() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    assert!(n >= 4, "test needs at least 4 layers");
    let donor = env.build_pipeline(2, Placement::NewContainers).unwrap();

    let edge_chain =
        ChainExecutor::build(env.edge.clone(), &env.manifest, 0..2, &env.weights).unwrap();
    let cloud_chain =
        ChainExecutor::build(env.cloud.clone(), &env.manifest, 3..n, &env.weights).unwrap();
    let broken = Pipeline::assemble(
        2,
        edge_chain,
        cloud_chain,
        env.link.clone(),
        env.clock.clone(),
        donor.edge_container.clone(),
        donor.cloud_container.clone(),
    );
    broken.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 9);
    let frames: Vec<_> = (0..3)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    let err = PipelinedRunner::new(2).run(&broken, &frames).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("cloud stage failed at frame 0"),
        "mismatched boundary must fail at the cloud stage: {msg}"
    );
}

/// A switch racing a pipelined burst: `route_batch` pins the active
/// pipeline, so the burst completes in full (ordered, no partial results)
/// while concurrent Scenario-A switches proceed — no hang, no error on
/// either side. Frames routed after the switch hit the new active.
#[test]
fn racing_switch_during_pipelined_burst_is_clean() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let strat =
        ScenarioA::deploy(env.clone(), n / 2, n / 3, PlacementCase::SameContainer).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 2);
    let frames: Vec<_> = (0..6)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    let router = strat.router.clone();

    std::thread::scope(|s| {
        let burst = s.spawn(|| router.route_batch(&frames, PipelinedRunner::new(2)));
        // Toggle active <-> standby while the burst is in flight.
        for _ in 0..4 {
            strat.switch().unwrap();
        }
        // Two clean outcomes are allowed: the burst pinned the pipeline
        // before any switch (full, ordered results), or a switch won the
        // race to the serve gate first (a loud "not serving" error).
        // Anything else — a hang, a panic, partial results — fails.
        match burst.join().expect("burst panicked") {
            Ok(outcomes) => {
                assert_eq!(outcomes.len(), frames.len(), "partial results returned");
                for (i, o) in outcomes.iter().enumerate() {
                    match o {
                        RouteOutcome::Processed(rep) => {
                            assert!(rep.output.to_vec::<f32>().is_ok(), "frame {i} corrupted")
                        }
                        _ => panic!("frame {i} dropped: no pause or fault was injected"),
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("not serving"), "unclean racing error: {msg}");
            }
        }
    });
    // After the dust settles the router still serves frames.
    match router.route(&frames[0]).unwrap() {
        RouteOutcome::Processed(_) => {}
        _ => panic!("router wedged after racing switches"),
    }
}

// ---------------------------------------------------------------------------
// Injected link faults: outages, retry exhaustion, degraded serving, and
// switch rollback (seeded + windowed, so every counter is asserted exactly)
// ---------------------------------------------------------------------------

/// A permanent outage window starting at t=0, shadowing everything.
fn total_outage(seed: u64) -> FaultPlan {
    FaultPlan::parse("outage@0..1000000", seed)
}

/// Link outage mid-stream: every transfer attempt aborts, so the
/// pipelined runner *drops* each frame after its retries — returning an
/// empty (not partial, not erroring) report set in both stage modes —
/// and the link/pipeline counters match the injected schedule exactly.
#[test]
fn link_outage_drops_frames_without_failing_the_runner() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let mut p = env.build_pipeline(n / 2, Placement::NewContainers).unwrap();
    p.retry = RetryPolicy {
        max_attempts: 2,
        base_backoff: Duration::from_millis(1),
        deadline: None,
    };
    p.transition(PipelineState::Active).unwrap();

    env.link.clear_fault_plan(); // isolate from any ambient profile
    env.link.install_fault_plan(total_outage(11));

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 3);
    let frames: Vec<_> = (0..4)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    for runner in [PipelinedRunner::new(2), PipelinedRunner::two_stage(2)] {
        let reports = runner.run(&p, &frames).unwrap();
        assert!(
            reports.is_empty(),
            "{:?}: every frame should drop on a dead link, got {} reports",
            runner.stages,
            reports.len()
        );
    }

    // 4 frames x 2 attempts x 2 runner modes, every attempt an outage.
    let link = env.link.fault_counters();
    assert_eq!(link.outage_aborts, 16);
    assert_eq!(link.failed_transfers, 16);
    assert_eq!(link.chunks_lost, 0);
    let stats = p.fault_stats.snapshot();
    assert_eq!(stats.retries, 8, "one retry per frame per mode");
    assert_eq!(stats.dropped_frames, 8);

    // Clearing the plan restores full service on the same pipeline.
    env.link.clear_fault_plan();
    let reports = PipelinedRunner::new(2).run(&p, &frames).unwrap();
    assert_eq!(reports.len(), frames.len(), "clean link must serve again");
}

/// Retry exhaustion with a fallback armed: the faulted frame drops, the
/// router flips to edge-only (degraded) serving, and a later successful
/// switch closes the window — with every counter pinned to the schedule.
#[test]
fn retry_exhaustion_flips_serving_to_the_edge_only_fallback() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let mut p = env.build_pipeline(n / 2, Placement::NewContainers).unwrap();
    p.retry = RetryPolicy {
        max_attempts: 3,
        base_backoff: Duration::from_millis(1),
        deadline: None,
    };
    let active = Arc::new(p);
    let router = Router::new(env.clock.clone(), active.clone()).unwrap();
    arm_degraded_fallback(&env, &router).unwrap();

    env.link.clear_fault_plan();
    env.link.install_fault_plan(total_outage(5));

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 4);
    let frames: Vec<_> = (0..4)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();

    // Frame 0 exhausts its 3 attempts and drops...
    match router.route(&frames[0]).unwrap() {
        RouteOutcome::DroppedFaulted => {}
        _ => panic!("first faulted frame must drop"),
    }
    assert!(router.in_degraded(), "exhaustion must open the degraded window");

    // ...and the window serves the rest edge-only, off the link entirely.
    for (i, f) in frames[1..].iter().enumerate() {
        match router.route(f).unwrap() {
            RouteOutcome::Degraded(rep) => {
                assert!(rep.output.to_vec::<f32>().is_ok(), "frame {} corrupted", i + 1);
                assert_eq!(rep.transfer_attempts, 0);
                assert_eq!(rep.t_transfer, Duration::ZERO);
            }
            _ => panic!("frame {} should serve degraded", i + 1),
        }
    }

    let link = env.link.fault_counters();
    assert_eq!(link.outage_aborts, 3, "exactly the dropped frame's attempts");
    assert_eq!(link.failed_transfers, 3);
    let pstats = active.fault_stats.snapshot();
    assert_eq!(pstats.retries, 2);
    assert_eq!(pstats.dropped_frames, 1);
    let rstats = router.fault_stats.snapshot();
    assert_eq!(rstats.degraded_frames, 3);
    assert_eq!(rstats.degraded_windows, 0, "window still open — not yet counted");

    // The cure is a successful switch: link heals, new pipeline swaps in,
    // the degraded window closes and is credited.
    env.link.clear_fault_plan();
    let replacement = Arc::new(env.build_pipeline(n / 3, Placement::NewContainers).unwrap());
    router.switch(replacement).unwrap();
    assert!(!router.in_degraded());
    let rstats = router.fault_stats.snapshot();
    assert_eq!(rstats.degraded_windows, 1);
    assert!(rstats.degraded_time > Duration::ZERO);
    match router.route(&frames[0]).unwrap() {
        RouteOutcome::Processed(_) => {}
        _ => panic!("router must serve normally after the switch"),
    }
}

/// A repartition whose pre-swap probe fails (dead link) must roll back:
/// the router stays on the old pipeline, the record is marked aborted
/// with an `aborted-switch` phase, and once the link heals the very same
/// repartition goes through.
#[test]
fn failed_switch_probe_rolls_back_to_the_old_pipeline() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    assert!(n >= 4, "test needs at least 4 layers");
    let strat = ScenarioB::deploy(env.clone(), n / 2)
        .unwrap()
        .with_case(PlacementCase::SameContainer);
    let router = strat.router.clone();
    let old = router.active();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 8);
    let probe = env.frame_literal(&cam.frame(0)).unwrap();
    match router.route(&probe).unwrap() {
        RouteOutcome::Processed(_) => {}
        _ => panic!("should serve before the fault"),
    }

    // Link down: the new pipeline's probe exhausts its retries, so the
    // guarded repartition aborts instead of swapping.
    env.link.clear_fault_plan();
    env.link.install_fault_plan(total_outage(9));
    let rec = strat.repartition_guarded(n / 3, &probe).unwrap();
    assert!(rec.aborted, "record must mark the rolled-back switch");
    assert!(
        rec.phases.iter().any(|(name, _)| name == "aborted-switch"),
        "phases: {:?}",
        rec.phases
    );
    assert!(
        Arc::ptr_eq(&old, &router.active()),
        "router must stay on the old pipeline"
    );
    assert_eq!(router.fault_stats.snapshot().aborted_switches, 1);

    // The old pipeline still serves once the link heals, and the same
    // repartition now succeeds.
    env.link.clear_fault_plan();
    match router.route(&probe).unwrap() {
        RouteOutcome::Processed(_) => {}
        _ => panic!("old pipeline must keep serving after the rollback"),
    }
    let rec = strat.repartition_guarded(n / 3, &probe).unwrap();
    assert!(!rec.aborted);
    assert!(!Arc::ptr_eq(&old, &router.active()), "healed repartition must swap");
    assert_eq!(router.active().split, n / 3);
}
