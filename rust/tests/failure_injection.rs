//! Failure injection: corrupted or missing artifacts must fail loudly and
//! precisely, never crash or silently mis-serve — and the overlapped
//! runner must drain cleanly on mid-burst stage faults, naming the
//! originating stage and frame index, without hangs or partial reports.

use std::fs;

use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{
    Pipeline, PipelinedRunner, Placement, PipelineState, PlacementCase, RouteOutcome, ScenarioA,
};
use neukonfig::device::FrameSource;
use neukonfig::models::{default_artifacts_dir, ArtifactIndex, ModelManifest};
use neukonfig::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};

fn with_artifact_copy(model: &str, f: impl FnOnce(&std::path::Path)) {
    let Ok(index) = ArtifactIndex::load(default_artifacts_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let src = index.root.join(model);
    let dst = std::env::temp_dir().join(format!("nk-fault-{}-{}", model, std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(&dst).unwrap();
    for entry in fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    f(&dst);
    let _ = fs::remove_dir_all(&dst);
}

#[test]
fn truncated_weights_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        let wpath = dir.join("weights.bin");
        let blob = fs::read(&wpath).unwrap();
        fs::write(&wpath, &blob[..blob.len() / 2]).unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let err = match WeightStore::load(&manifest) {
            Err(e) => e,
            Ok(_) => panic!("truncated weights accepted"),
        };
        assert!(err.to_string().contains("bytes"), "got: {err}");
    });
}

#[test]
fn corrupt_hlo_fails_at_compile_not_at_run() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::write(dir.join("layer_00.hlo.txt"), "HloModule garbage\nnot hlo").unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("t", 1.0).unwrap();
        let err = match ChainExecutor::build(domain, &manifest, 0..1, &weights) {
            Err(e) => e,
            Ok(_) => panic!("corrupt HLO accepted"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("layer_00"), "error should name the artifact: {msg}");
    });
}

#[test]
fn missing_hlo_file_is_reported() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::remove_file(dir.join("layer_01.hlo.txt")).unwrap();
        let manifest = ModelManifest::load(dir).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("t", 1.0).unwrap();
        // Layer 0 still builds.
        assert!(ChainExecutor::build(domain.clone(), &manifest, 0..1, &weights).is_ok());
        // Layer 1 does not.
        assert!(ChainExecutor::build(domain, &manifest, 1..2, &weights).is_err());
    });
}

#[test]
fn manifest_with_broken_shapes_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        let mpath = dir.join("manifest.json");
        let text = fs::read_to_string(&mpath).unwrap();
        // Break the chaining: first layer's output shape tampered.
        let broken = text.replacen("\"output_shape\": [", "\"output_shape\": [77, ", 1);
        fs::write(&mpath, broken).unwrap();
        assert!(ModelManifest::load(dir).is_err());
    });
}

#[test]
fn wrong_input_shape_rejected_at_execute() {
    let Ok(index) = ArtifactIndex::load(default_artifacts_dir()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model("mobilenetv2").unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let domain = Domain::new("t", 1.0).unwrap();
    let chain = ChainExecutor::build(domain, &manifest, 0..1, &weights).unwrap();
    // 8x8 frame against a 64x64 executable.
    let bad = literal_from_f32(&[1, 8, 8, 3], &vec![0.0; 192]).unwrap();
    assert!(chain.run_raw(&bad).is_err());
}

#[test]
fn literal_shape_mismatch_rejected() {
    assert!(literal_from_f32(&[2, 2], &[1.0, 2.0, 3.0]).is_err());
}

#[test]
fn garbage_manifest_json_rejected() {
    with_artifact_copy("mobilenetv2", |dir| {
        fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(ModelManifest::load(dir).is_err());
    });
}

// ---------------------------------------------------------------------------
// Pipelined-runner fault injection (artifact-gated like the suites above)
// ---------------------------------------------------------------------------

const MODEL: &str = "mobilenetv2";

/// Mid-burst edge-chain fault: frame 2 of 5 has the wrong shape, so the
/// edge stage fails after two good frames. Both stage modes must return a
/// single error naming the edge stage and the frame index — no hang, no
/// partial report set.
#[test]
fn edge_fault_mid_burst_names_stage_and_frame() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let p = env.build_pipeline(n / 2, Placement::NewContainers).unwrap();
    p.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 5);
    let mut frames: Vec<_> = (0..5)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    frames[2] = literal_from_f32(&[1, 8, 8, 3], &vec![0.1; 192]).unwrap();

    for runner in [PipelinedRunner::new(2), PipelinedRunner::two_stage(2)] {
        let err = match runner.run(&p, &frames) {
            Err(e) => e,
            Ok(_) => panic!("bad frame accepted ({:?})", runner.stages),
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("edge stage failed at frame 2"),
            "{:?}: error must name stage + frame, got: {msg}",
            runner.stages
        );
    }
}

/// Cloud-chain fault: at split 0 the (empty) edge chain passes the frame
/// through untouched, so a malformed frame first explodes in the cloud
/// stage. The error must name the cloud stage and frame index.
#[test]
fn cloud_fault_mid_burst_names_stage_and_frame() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let p = env.build_pipeline(0, Placement::NewContainers).unwrap();
    p.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 6);
    let mut frames: Vec<_> = (0..4)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    frames[1] = literal_from_f32(&[1, 8, 8, 3], &vec![0.2; 192]).unwrap();

    for runner in [PipelinedRunner::new(3), PipelinedRunner::two_stage(3)] {
        let err = match runner.run(&p, &frames) {
            Err(e) => e,
            Ok(_) => panic!("bad frame accepted ({:?})", runner.stages),
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("cloud stage failed at frame 1"),
            "{:?}: error must name stage + frame, got: {msg}",
            runner.stages
        );
    }
}

/// Deliberately mismatched chains via the test-support constructor: the
/// edge chain ends at layer 2 but the cloud chain starts at layer 3, so
/// every frame's intermediate has the wrong shape for the cloud stage.
/// The runner must fail at frame 0, cleanly.
#[test]
fn mismatched_chain_boundary_fails_cleanly() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    assert!(n >= 4, "test needs at least 4 layers");
    let donor = env.build_pipeline(2, Placement::NewContainers).unwrap();

    let edge_chain =
        ChainExecutor::build(env.edge.clone(), &env.manifest, 0..2, &env.weights).unwrap();
    let cloud_chain =
        ChainExecutor::build(env.cloud.clone(), &env.manifest, 3..n, &env.weights).unwrap();
    let broken = Pipeline::assemble(
        2,
        edge_chain,
        cloud_chain,
        env.link.clone(),
        env.clock.clone(),
        donor.edge_container.clone(),
        donor.cloud_container.clone(),
    );
    broken.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 9);
    let frames: Vec<_> = (0..3)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    let err = PipelinedRunner::new(2).run(&broken, &frames).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("cloud stage failed at frame 0"),
        "mismatched boundary must fail at the cloud stage: {msg}"
    );
}

/// A switch racing a pipelined burst: `route_batch` pins the active
/// pipeline, so the burst completes in full (ordered, no partial results)
/// while concurrent Scenario-A switches proceed — no hang, no error on
/// either side. Frames routed after the switch hit the new active.
#[test]
fn racing_switch_during_pipelined_burst_is_clean() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let strat =
        ScenarioA::deploy(env.clone(), n / 2, n / 3, PlacementCase::SameContainer).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 2);
    let frames: Vec<_> = (0..6)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();
    let router = strat.router.clone();

    std::thread::scope(|s| {
        let burst = s.spawn(|| router.route_batch(&frames, PipelinedRunner::new(2)));
        // Toggle active <-> standby while the burst is in flight.
        for _ in 0..4 {
            strat.switch().unwrap();
        }
        // Two clean outcomes are allowed: the burst pinned the pipeline
        // before any switch (full, ordered results), or a switch won the
        // race to the serve gate first (a loud "not serving" error).
        // Anything else — a hang, a panic, partial results — fails.
        match burst.join().expect("burst panicked") {
            Ok(outcomes) => {
                assert_eq!(outcomes.len(), frames.len(), "partial results returned");
                for (i, o) in outcomes.iter().enumerate() {
                    match o {
                        RouteOutcome::Processed(rep) => {
                            assert!(rep.output.to_vec::<f32>().is_ok(), "frame {i} corrupted")
                        }
                        RouteOutcome::DroppedPaused => {
                            panic!("frame {i} dropped: router never paused")
                        }
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("not serving"), "unclean racing error: {msg}");
            }
        }
    });
    // After the dust settles the router still serves frames.
    match router.route(&frames[0]).unwrap() {
        RouteOutcome::Processed(_) => {}
        RouteOutcome::DroppedPaused => panic!("router wedged after racing switches"),
    }
}
