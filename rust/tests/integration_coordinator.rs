//! Integration: the full NEUKONFIG coordinator over real PJRT artifacts.
//!
//! These tests reproduce the paper's qualitative claims end-to-end:
//! Pause-and-Resume blacks out the edge for seconds; Dynamic Switching
//! Scenario A switches in under a millisecond; Scenario B sits in between,
//! with Case 2 cheaper than Case 1; memory follows Table I.

use std::sync::Arc;

use neukonfig::coordinator::experiments::{measure_downtime, Approach, ExperimentSetup};
use neukonfig::coordinator::{
    EdgeCloudEnv, NetworkMonitor, PauseResume, PlacementCase, Planner, RouteOutcome, ScenarioA,
    ScenarioB,
};
use neukonfig::config::ExperimentConfig;
use neukonfig::device::FrameSource;
use neukonfig::netsim::Schedule;
use neukonfig::profiler::ModelProfile;
use neukonfig::stress::StressProfile;

const MODEL: &str = "mobilenetv2"; // smaller artifacts -> faster compiles

fn setup() -> Option<ExperimentSetup> {
    ExperimentSetup::load().ok()
}

fn env_and_profile(setup: &ExperimentSetup) -> (Arc<EdgeCloudEnv>, ModelProfile) {
    let env = setup.env(MODEL).expect("env");
    // Analytic profile keeps these tests fast; the measured profile is
    // exercised by the examples and benches.
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    (env, profile)
}

#[test]
fn downtime_ordering_matches_paper() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (env, profile) = env_and_profile(&setup);
    let cfg = &setup.cfg;
    let no_stress = StressProfile::none();

    let dt = |approach| {
        measure_downtime(
            &env,
            &profile,
            approach,
            no_stress,
            cfg.network.high_mbps,
            cfg.network.low_mbps,
        )
        .unwrap()
        .expect("no OOM expected")
    };

    let baseline = dt(Approach::PauseResume);
    let a1 = dt(Approach::ScenarioA(PlacementCase::NewContainer));
    let a2 = dt(Approach::ScenarioA(PlacementCase::SameContainer));
    let b1 = dt(Approach::ScenarioB(PlacementCase::NewContainer));
    let b2 = dt(Approach::ScenarioB(PlacementCase::SameContainer));

    println!(
        "baseline={:?} A1={:?} A2={:?} B1={:?} B2={:?}",
        baseline.total, a1.total, a2.total, b1.total, b2.total
    );

    // Paper Fig 11-13 ordering: baseline (~6 s) >> B1 (~1.9 s) > B2
    // (~0.6 s) >> A (<1 ms).
    assert!(baseline.total > b1.total, "baseline must dominate B1");
    assert!(b1.total > b2.total, "B1 (container start) > B2");
    assert!(b2.total > a1.total, "B2 > scenario A");
    // Scenario A: switch only, both cases equal in kind — sub-millisecond.
    assert!(a1.total < std::time::Duration::from_millis(1), "A1 {:?}", a1.total);
    assert!(a2.total < std::time::Duration::from_millis(1), "A2 {:?}", a2.total);
    // Baseline must be an order of magnitude above B2 (paper: 6 s vs 0.6 s).
    assert!(baseline.total.as_secs_f64() / b2.total.as_secs_f64() > 5.0);
}

#[test]
fn downtime_insensitive_to_stress() {
    // Paper: "CPU and memory availability ... do not change the service
    // downtime" (within measurement noise; the real compile component can
    // vary, so compare with a generous band).
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (env, profile) = env_and_profile(&setup);
    let cfg = &setup.cfg;

    let mut totals = Vec::new();
    for sp in [StressProfile::new(1.0, 1.0), StressProfile::new(0.25, 0.5)] {
        let rec = measure_downtime(
            &env,
            &profile,
            Approach::PauseResume,
            sp,
            cfg.network.high_mbps,
            cfg.network.low_mbps,
        )
        .unwrap()
        .expect("fits in memory");
        totals.push(rec.total.as_secs_f64());
    }
    let ratio = totals[1] / totals[0];
    assert!(
        (0.5..2.0).contains(&ratio),
        "downtime should be stress-insensitive, got ratio {ratio}"
    );
}

#[test]
fn oom_at_low_memory_availability() {
    // Paper: no results at <=10 % memory availability.
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (env, profile) = env_and_profile(&setup);
    let cfg = &setup.cfg;
    let rec = measure_downtime(
        &env,
        &profile,
        Approach::PauseResume,
        StressProfile::new(1.0, 0.10),
        cfg.network.high_mbps,
        cfg.network.low_mbps,
    )
    .unwrap();
    assert!(rec.is_none(), "pipeline must not be admitted at 10% memory");
}

#[test]
fn table1_memory_semantics() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = ExperimentConfig::new();
    let pipeline_mb = cfg.memory.pipeline_mb;

    // Scenario A Case 1: standby in its own containers -> 2x initial.
    let env = setup.env(MODEL).unwrap();
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let lat = cfg.network.latency;
    let hi = profile.optimal_split(cfg.network.high_mbps, lat, 1.0);
    let lo = profile.optimal_split(cfg.network.low_mbps, lat, 1.0);
    let _a1 = ScenarioA::deploy(env.clone(), hi, lo, PlacementCase::NewContainer).unwrap();
    let containers: f64 = env
        .edge_host
        .ledger
        .entries()
        .iter()
        .filter(|(l, _)| l.starts_with("container:"))
        .map(|(_, m)| m)
        .sum();
    assert!((containers - 2.0 * pipeline_mb).abs() < 1e-6, "A1 wants 2x, got {containers}");

    // Scenario A Case 2: standby in the same containers -> 1x.
    let env2 = setup.env(MODEL).unwrap();
    let _a2 = ScenarioA::deploy(env2.clone(), hi, lo, PlacementCase::SameContainer).unwrap();
    let containers2: f64 = env2
        .edge_host
        .ledger
        .entries()
        .iter()
        .filter(|(l, _)| l.starts_with("container:"))
        .map(|(_, m)| m)
        .sum();
    assert!((containers2 - pipeline_mb).abs() < 1e-6, "A2 wants 1x, got {containers2}");

    // Scenario B Case 1: transient 2x during switching, settles to 1x.
    let env3 = setup.env(MODEL).unwrap();
    let b1 = ScenarioB::deploy(env3.clone(), hi)
        .unwrap()
        .with_case(PlacementCase::NewContainer);
    env3.edge_host.ledger.reset_peak();
    b1.repartition(lo).unwrap();
    let peak = env3.edge_host.ledger.peak_mb();
    let settled: f64 = env3
        .edge_host
        .ledger
        .entries()
        .iter()
        .filter(|(l, _)| l.starts_with("container:"))
        .map(|(_, m)| m)
        .sum();
    assert!(peak >= 2.0 * pipeline_mb, "B1 transient peak {peak}");
    assert!((settled - pipeline_mb).abs() < 1e-6, "B1 settles to 1x, got {settled}");
}

#[test]
fn monitor_planner_loop_drives_repartition() {
    // The full automatic loop: trace event -> monitor -> planner -> switch.
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (env, profile) = env_and_profile(&setup);
    let cfg = &setup.cfg;
    let lat = cfg.network.latency;
    let planner = Planner::new(profile.clone(), lat);

    let hi_plan = planner.plan(cfg.network.high_mbps);
    let lo_plan = planner.plan(cfg.network.low_mbps);
    assert_ne!(hi_plan.split, lo_plan.split, "toggle must move the split");

    let strat = ScenarioB::deploy(env.clone(), hi_plan.split)
        .unwrap()
        .with_case(PlacementCase::SameContainer);
    let monitor = NetworkMonitor::new(
        env.link.clone(),
        Schedule::new(vec![(std::time::Duration::from_secs(5), cfg.network.low_mbps)]),
    );

    // Before the event: no change.
    assert!(monitor.poll(std::time::Duration::from_secs(1)).is_none());
    // At t=5s the bandwidth drops; the planner proposes a new split.
    let change = monitor.poll(std::time::Duration::from_secs(5)).expect("event");
    let plan = planner
        .should_repartition(strat.router.active().split, change.to_mbps)
        .expect("plan");
    let rec = strat.repartition(plan.split).unwrap();
    assert_eq!(strat.router.active().split, lo_plan.split);
    assert!(rec.total > std::time::Duration::ZERO);
}

#[test]
fn router_serves_and_drops_frames() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let strat = PauseResume::deploy(env.clone(), 3).unwrap();
    let mut cam = FrameSource::new(&env.manifest.input_shape, 15.0, 7);

    // Serve two frames.
    for _ in 0..2 {
        let f = cam.next_frame();
        let lit = env.frame_literal(&f).unwrap();
        match strat.router.route(&lit).unwrap() {
            RouteOutcome::Processed(rep) => {
                assert!(rep.total() > std::time::Duration::ZERO);
                assert!(rep.t_transfer >= env.cfg.network.latency);
            }
            _ => panic!("should process, not drop, while active"),
        }
    }

    // Pause: frames are dropped.
    strat.router.pause().unwrap();
    strat.router.set_downtime(true);
    let f = cam.next_frame();
    let lit = env.frame_literal(&f).unwrap();
    assert!(matches!(
        strat.router.route(&lit).unwrap(),
        RouteOutcome::DroppedPaused
    ));
    strat.router.set_downtime(false);
    strat.router.resume(None).unwrap();

    let s = strat.router.stats.snapshot();
    assert_eq!(s.produced, 3);
    assert_eq!(s.processed, 2);
    assert_eq!(s.dropped, 1);
    assert_eq!(s.dropped_during_downtime, 1);
    assert!(strat.router.latency.count() == 2);
}

#[test]
fn scenario_a_standby_recycles() {
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (env, profile) = env_and_profile(&setup);
    let cfg = &setup.cfg;
    let lat = cfg.network.latency;
    let hi = profile.optimal_split(cfg.network.high_mbps, lat, 1.0);
    let lo = profile.optimal_split(cfg.network.low_mbps, lat, 1.0);

    let strat = ScenarioA::deploy(env.clone(), hi, lo, PlacementCase::SameContainer).unwrap();
    assert_eq!(strat.standby_split(), Some(lo));

    // Toggle 20 -> 5: switch to the standby; the old active becomes standby.
    env.link.set_bandwidth(cfg.network.low_mbps);
    strat.switch().unwrap();
    assert_eq!(strat.router.active().split, lo);
    assert_eq!(strat.standby_split(), Some(hi));

    // Toggle back 5 -> 20 without any rebuild.
    env.link.set_bandwidth(cfg.network.high_mbps);
    let rec = strat.switch().unwrap();
    assert_eq!(strat.router.active().split, hi);
    assert_eq!(strat.standby_split(), Some(lo));
    assert!(rec.total < std::time::Duration::from_millis(1));

    // ensure_standby with matching split is free.
    assert_eq!(strat.ensure_standby(lo).unwrap(), std::time::Duration::ZERO);
    // Rebuild standby at a different split (background work).
    let d = strat.ensure_standby(lo + 1).unwrap();
    assert!(d > std::time::Duration::ZERO);
    assert_eq!(strat.standby_split(), Some(lo + 1));
}

#[test]
fn e2e_inference_correct_through_pipeline() {
    // A routed frame produces the same logits as the raw chain.
    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let env = setup.env(MODEL).unwrap();
    let n = env.manifest.num_layers();
    let strat = PauseResume::deploy(env.clone(), n / 2).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 3);
    let f = cam.frame(0);
    let lit = env.frame_literal(&f).unwrap();
    let RouteOutcome::Processed(rep) = strat.router.route(&lit).unwrap() else {
        panic!("expected processing");
    };
    let probs = rep.output.to_vec::<f32>().unwrap();
    let sum: f32 = probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "softmax sums to 1, got {sum}");
    assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn serving_daemon_end_to_end() {
    // The full deployable loop on a short realtime run: camera thread ->
    // batcher -> serving/control thread, with one scheduled toggle.
    use neukonfig::clock::Clock;
    use neukonfig::coordinator::server::{serve, ServerConfig, Strategy};
    use neukonfig::coordinator::{EdgeCloudEnv, TriggerPolicy};
    use std::time::Duration;

    let Some(setup) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = setup.manifest(MODEL).unwrap();
    let env = Arc::new(
        EdgeCloudEnv::new(setup.cfg.clone(), manifest, Clock::realtime()).unwrap(),
    );
    let profile = neukonfig::profiler::default_analytic(&env.manifest);
    let planner = Planner::new(profile, setup.cfg.network.latency);
    let hi = planner.plan(setup.cfg.network.high_mbps).split;
    let lo = planner.plan(setup.cfg.network.low_mbps).split;

    let strat = Strategy::deploy("scenario-a-case2", env.clone(), hi, lo).unwrap();
    let monitor = NetworkMonitor::new(
        env.link.clone(),
        Schedule::new(vec![(Duration::from_secs(1), setup.cfg.network.low_mbps)]),
    );
    let report = serve(
        &strat,
        &env,
        &monitor,
        &planner,
        ServerConfig {
            fps: 20.0,
            run_for: Duration::from_secs(3),
            policy: TriggerPolicy::immediate(),
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(report.repartitions.len(), 1, "one toggle -> one repartition");
    assert_eq!(report.repartitions[0].1, lo);
    assert!(report.downtimes[0].total < Duration::from_millis(1), "A2 switch");
    let s = strat.router().stats.snapshot();
    assert!(s.produced >= 30, "camera produced {}", s.produced);
    assert!(s.processed > 0, "frames served");
    assert_eq!(s.produced, s.processed + s.dropped + pending_in_queue(&s));
}

// Frames still in the batcher at shutdown are neither processed nor
// dropped; reconcile conservation with the difference.
fn pending_in_queue(s: &neukonfig::metrics::FrameStatsInner) -> u64 {
    s.produced - s.processed - s.dropped
}
