//! The lint pass, end to end: the real tree must be clean, and every
//! fixture under rust/lint_fixtures/ must trip exactly the rule it is
//! named for. This is the executable contract for `neukonfig_lint` —
//! CI runs the binary, but these tests pin the per-rule behaviour.

use std::path::{Path, PathBuf};

use neukonfig::lint::{lint_source, lint_tree, Finding, LintConfig, Rule};

fn repo(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn lint_fixture(rel: &str) -> Vec<Finding> {
    let path = repo(rel);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    lint_source(&path, &src, &LintConfig::default())
}

fn rules(findings: &[Finding]) -> Vec<Rule> {
    findings.iter().map(|f| f.rule).collect()
}

/// The committed source tree holds every invariant — the same check
/// `cargo run --bin neukonfig_lint` performs in CI.
#[test]
fn source_tree_is_clean() {
    let findings = lint_tree(&repo("rust/src"), &LintConfig::default())
        .expect("walking rust/src");
    assert!(
        findings.is_empty(),
        "rust/src has lint violations:\n{}",
        findings
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn bare_lock_fixture_trips_three_ways() {
    let f = lint_fixture("rust/lint_fixtures/bare_lock.rs");
    assert_eq!(rules(&f), vec![Rule::BareLock; 3], "{f:?}");
    // The split `.lock()\n.unwrap()` chain is caught and anchored at the
    // `.lock()` line — the whitespace-insensitive matcher's whole point.
    let split = &f[1];
    assert!(split.snippet.contains(".lock()"), "{split}");
}

#[test]
fn wall_clock_fixture_trips_but_not_in_strings_or_comments() {
    let f = lint_fixture("rust/lint_fixtures/wall_clock.rs");
    assert_eq!(rules(&f), vec![Rule::WallClock, Rule::WallClock], "{f:?}");
}

#[test]
fn unsafe_fixture_trips_block_and_fn() {
    let f = lint_fixture("rust/lint_fixtures/unsafe_code.rs");
    assert_eq!(rules(&f), vec![Rule::UnsafeCode, Rule::UnsafeCode], "{f:?}");
}

#[test]
fn unsafe_allowlist_requires_safety_comment_too() {
    let path = repo("rust/lint_fixtures/unsafe_code.rs");
    let src = std::fs::read_to_string(&path).unwrap();
    let cfg = LintConfig {
        unsafe_allowlist: vec!["lint_fixtures/unsafe_code.rs".into()],
        ..LintConfig::default()
    };
    let f = lint_source(&path, &src, &cfg);
    // Allowlisting the file waives the SAFETY-commented block but NOT the
    // uncommented `unsafe fn`.
    assert_eq!(rules(&f), vec![Rule::UnsafeCode], "{f:?}");
    assert!(f[0].snippet.contains("raw_write"), "{}", f[0]);
}

#[test]
fn unbounded_channel_fixture_trips_in_coordinator_scope() {
    let f = lint_fixture("rust/lint_fixtures/coordinator/unbounded_channel.rs");
    assert_eq!(
        rules(&f),
        vec![Rule::UnboundedChannel, Rule::UnboundedChannel],
        "{f:?}"
    );
}

#[test]
fn unbounded_channel_out_of_scope_is_ignored() {
    // Same source, path without a coordinator/ component: rule is scoped.
    let src =
        std::fs::read_to_string(repo("rust/lint_fixtures/coordinator/unbounded_channel.rs"))
            .unwrap();
    let f = lint_source(Path::new("rust/lint_fixtures/elsewhere.rs"), &src, &LintConfig::default());
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn raw_sleep_fixture_trips() {
    let f = lint_fixture("rust/lint_fixtures/raw_sleep.rs");
    assert_eq!(rules(&f), vec![Rule::RawSleep], "{f:?}");
}

#[test]
fn clean_fixture_is_clean() {
    // Covers the poison-recovering lock idiom, the allow-marker waiver,
    // bounded channels, and the cfg(test) exemption in one file.
    let f = lint_fixture("rust/lint_fixtures/clean.rs");
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn every_fixture_exit_status_matches_binary_contract() {
    // The binary exits nonzero iff findings are non-empty; mirror that
    // mapping over every fixture so the CI commands stay honest.
    let expect_dirty = [
        "rust/lint_fixtures/bare_lock.rs",
        "rust/lint_fixtures/wall_clock.rs",
        "rust/lint_fixtures/unsafe_code.rs",
        "rust/lint_fixtures/raw_sleep.rs",
        "rust/lint_fixtures/coordinator/unbounded_channel.rs",
    ];
    for rel in expect_dirty {
        assert!(!lint_fixture(rel).is_empty(), "{rel} should trip its rule");
    }
    assert!(lint_fixture("rust/lint_fixtures/clean.rs").is_empty());
}

#[test]
fn findings_are_ordered_by_line() {
    let f = lint_fixture("rust/lint_fixtures/bare_lock.rs");
    let lines: Vec<usize> = f.iter().map(|x| x.line).collect();
    let mut sorted = lines.clone();
    sorted.sort_unstable();
    assert_eq!(lines, sorted);
}
