//! Property tests for the activation-transfer codec: every codec must obey
//! its documented contract on random *and* adversarial tensors.
//!
//! * `Fp32` — bitwise round trip, always.
//! * `Fp16` — absolute reconstruction error bounded by
//!   `|x| * 2^-11 + 3e-8` for inputs within the finite f16 range, overflow
//!   clamped to ±65504 (never an infinity on the wire), and every finite
//!   binary16 bit pattern survives an exact decode→encode round trip.
//! * `Int8` — error bounded by half a quantisation step (plus one f32 ulp
//!   of the reconstructed magnitude), endpoints and constant tensors exact,
//!   extreme f32 spans handled without overflow.
//!
//! `proptest` is unavailable offline, so cases come from the in-tree
//! deterministic PRNG; failure messages carry the case coordinates.

use neukonfig::codec::{
    decode_literal, decode_to_f32s, encode_f32s, encode_literal, f16_bits_to_f32,
    f32_to_f16_bits, EncodedPayload, TransferCodec, INT8_HEADER_BYTES,
};
use neukonfig::runtime::literal_from_f32;
use neukonfig::util::prng::Prng;

const CASES: usize = 100;

/// Uniform tensor in [lo, hi]. Interpolates in f64 — `hi - lo` can exceed
/// f32::MAX (e.g. a ±3e38 span), which would overflow `next_f32_range`.
fn random_tensor(rng: &mut Prng, lo: f32, hi: f32) -> Vec<f32> {
    let n = 1 + rng.next_below(512);
    (0..n)
        .map(|_| (lo as f64 + (hi as f64 - lo as f64) * rng.next_f64()) as f32)
        .collect()
}

/// Tensors built to hit codec edge cases: constants, zeros, f32 denormals,
/// huge spans, single elements, sign flips around zero.
fn adversarial_tensors() -> Vec<Vec<f32>> {
    vec![
        vec![0.0; 64],
        vec![-0.0; 3],
        vec![1.25; 200],
        vec![-7.5],
        vec![1e-40, 2e-39, 1e-38, -1e-40],
        vec![-3.0e38, 3.0e38],
        vec![-1.0, 0.0, 1.0],
        vec![65504.0, -65504.0, 0.5],
        vec![f32::MIN_POSITIVE, -f32::MIN_POSITIVE],
    ]
}

#[test]
fn fp32_round_trip_is_bitwise_on_random_and_adversarial_tensors() {
    let mut rng = Prng::new(0xF32);
    let mut tensors = adversarial_tensors();
    for _ in 0..CASES {
        tensors.push(random_tensor(&mut rng, -3.0e38, 3.0e38));
    }
    for (case, xs) in tensors.iter().enumerate() {
        let back = decode_to_f32s(&encode_f32s(TransferCodec::Fp32, xs));
        assert_eq!(back.len(), xs.len(), "case {case}: length");
        for (i, (a, b)) in xs.iter().zip(&back).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} elem {i}: {a} vs {b}");
        }
    }
}

#[test]
fn fp16_error_stays_within_documented_bound() {
    let mut rng = Prng::new(0xF16);
    let mut tensors = adversarial_tensors();
    for _ in 0..CASES {
        tensors.push(random_tensor(&mut rng, -1.0e4, 1.0e4));
    }
    for (case, xs) in tensors.iter().enumerate() {
        let back = decode_to_f32s(&encode_f32s(TransferCodec::Fp16, xs));
        assert_eq!(back.len(), xs.len(), "case {case}: length");
        for (i, (&x, &y)) in xs.iter().zip(&back).enumerate() {
            if x.abs() > 65504.0 {
                // Overflow clamps to the largest finite f16, same sign.
                assert_eq!(y.abs(), 65504.0, "case {case} elem {i}: {x} -> {y}");
                assert_eq!(
                    y.is_sign_negative(),
                    x.is_sign_negative(),
                    "case {case} elem {i}: sign lost"
                );
                continue;
            }
            let err = (x as f64 - y as f64).abs();
            let bound = x.abs() as f64 / 2048.0 + 3.0e-8;
            assert!(
                err <= bound,
                "case {case} elem {i}: {x} -> {y}, err {err} > bound {bound}"
            );
        }
    }
}

#[test]
fn fp16_every_finite_bit_pattern_round_trips_exactly() {
    // decode(h) is exact in f32, so encode(decode(h)) must give h back for
    // every finite binary16 — both signs, normals and subnormals alike.
    for h in 0..0x7c00u16 {
        for sign in [0u16, 0x8000] {
            let bits = sign | h;
            let x = f16_bits_to_f32(bits);
            assert_eq!(
                f32_to_f16_bits(x),
                bits,
                "bit pattern {bits:#06x} (value {x}) did not round trip"
            );
        }
    }
}

#[test]
fn int8_error_stays_within_half_a_step() {
    let mut rng = Prng::new(0x18);
    let mut tensors = adversarial_tensors();
    for _ in 0..CASES {
        // Random span, including asymmetric and very large ranges.
        let a = ((rng.next_f64() * 2.0 - 1.0) * 3.0e38) as f32;
        let b = ((rng.next_f64() * 2.0 - 1.0) * 3.0e38) as f32;
        tensors.push(random_tensor(&mut rng, a.min(b), a.max(b)));
    }
    for (case, xs) in tensors.iter().enumerate() {
        let enc = encode_f32s(TransferCodec::Int8, xs);
        let EncodedPayload::Int8 { ref q, min, scale } = enc else {
            panic!("case {case}: wrong payload variant");
        };
        assert_eq!(q.len(), xs.len(), "case {case}: length");
        assert!(min.is_finite() && scale.is_finite(), "case {case}: params");
        let back = decode_to_f32s(&enc);
        for (i, (&x, &y)) in xs.iter().zip(&back).enumerate() {
            assert!(y.is_finite(), "case {case} elem {i}: non-finite {y}");
            // Half a quantisation step, plus one f32 ulp-ish term for the
            // final f64 -> f32 rounding of the reconstruction.
            let err = (x as f64 - y as f64).abs();
            let bound = scale * 0.5 + x.abs() as f64 * 1e-6;
            assert!(
                err <= bound,
                "case {case} elem {i}: {x} -> {y}, err {err} > bound {bound}"
            );
        }
        // The min endpoint always lands exactly on grid point 0 (q = 0
        // decodes to `min` verbatim). The max endpoint decodes through
        // `min + 255 * scale`, whose f64 rounding (~span * 2^-52) only
        // survives the cast back to f32 when it is below the f32 ulp at
        // `hi` — guaranteed when |hi| is not vanishingly small vs the span.
        let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lo_i = xs.iter().position(|&v| v == lo).unwrap();
        let hi_i = xs.iter().position(|&v| v == hi).unwrap();
        assert_eq!(back[lo_i], lo, "case {case}: min endpoint");
        let span = hi as f64 - lo as f64;
        if hi.abs() as f64 * 1.0e7 >= span {
            assert_eq!(back[hi_i], hi, "case {case}: max endpoint");
        }
    }
}

#[test]
fn int8_constant_and_single_element_tensors_are_exact() {
    let mut rng = Prng::new(0xC0);
    for case in 0..CASES {
        let v = rng.next_f32_range(-1.0e6, 1.0e6);
        let n = 1 + rng.next_below(32);
        let xs = vec![v; n];
        let back = decode_to_f32s(&encode_f32s(TransferCodec::Int8, &xs));
        assert_eq!(back, xs, "case {case}: constant {v} x{n}");
    }
}

#[test]
fn literal_round_trip_preserves_shape_for_every_codec() {
    let dims = [2usize, 3, 4];
    let n: usize = dims.iter().product();
    let mut rng = Prng::new(0x117);
    let xs: Vec<f32> = (0..n).map(|_| rng.next_f32_range(-100.0, 100.0)).collect();
    let lit = literal_from_f32(&dims, &xs).unwrap();
    let raw_bytes = n * 4;

    for codec in [TransferCodec::Fp32, TransferCodec::Fp16, TransferCodec::Int8] {
        let enc = encode_literal(codec, &lit).unwrap();
        assert_eq!(enc.dims, dims, "{codec:?}: dims");
        assert_eq!(enc.raw_bytes, raw_bytes, "{codec:?}: raw bytes");
        // wire_bytes must agree with the planner's shared byte model.
        assert_eq!(
            enc.wire_bytes(),
            codec.encoded_bytes(raw_bytes),
            "{codec:?}: wire-byte model mismatch"
        );
        let back = decode_literal(&enc).unwrap();
        let ys = back.to_vec::<f32>().unwrap();
        assert_eq!(ys.len(), n, "{codec:?}: element count");
        if codec == TransferCodec::Fp32 {
            for (i, (a, b)) in xs.iter().zip(&ys).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "fp32 elem {i}");
            }
            assert!((enc.compression_ratio() - 1.0).abs() < 1e-12);
        } else {
            assert!(enc.compression_ratio() > 1.9, "{codec:?}: ratio");
        }
    }
    // And the int8 header really is the only overhead.
    let enc8 = encode_literal(TransferCodec::Int8, &lit).unwrap();
    assert_eq!(enc8.wire_bytes(), n + INT8_HEADER_BYTES);
}
