//! Property-based tests over coordinator invariants (routing, batching,
//! state, accounting). `proptest` is unavailable offline, so cases are
//! generated with the in-tree deterministic PRNG; every failure message
//! includes the case seed for reproduction.

use std::time::Duration;

use neukonfig::container::MemoryLedger;
use neukonfig::coordinator::batcher::{Batcher, Offer};
use neukonfig::coordinator::flow::simulate_window;
use neukonfig::coordinator::state::PipelineState;
use neukonfig::netsim::{transfer_time, Schedule};
use neukonfig::profiler::{LayerProfile, ModelProfile};
use neukonfig::util::json;
use neukonfig::util::prng::Prng;
use neukonfig::util::stats::{percentile_sorted, Summary, Welford};

const CASES: usize = 200;

/// Random profile generator: 1..30 layers with arbitrary times/sizes.
fn random_profile(rng: &mut Prng) -> ModelProfile {
    let n = 1 + rng.next_below(30);
    let layers = (0..n)
        .map(|i| LayerProfile {
            index: i,
            name: format!("l{i}"),
            kind: "conv".into(),
            edge_time: Duration::from_micros(rng.next_range(10, 50_000)),
            cloud_time: Duration::from_micros(rng.next_range(10, 50_000)),
            output_bytes: rng.next_range(16, 4_000_000) as usize,
            ..Default::default()
        })
        .collect();
    ModelProfile {
        model: "rand".into(),
        input_bytes: rng.next_range(16, 4_000_000) as usize,
        layers,
    }
}

#[test]
fn prop_optimal_split_is_argmin() {
    let mut rng = Prng::new(0xA11CE);
    for case in 0..CASES {
        let p = random_profile(&mut rng);
        let bw = rng.next_f32_range(0.5, 100.0) as f64;
        let lat = Duration::from_millis(rng.next_range(0, 100));
        let cpu = rng.next_f32_range(0.05, 1.0) as f64;
        let opt = p.optimal_split(bw, lat, cpu);
        let best = p.breakdown(opt, bw, lat, cpu).total();
        for k in 0..=p.layers.len() {
            assert!(
                best <= p.breakdown(k, bw, lat, cpu).total(),
                "case {case}: split {opt} not optimal vs {k}"
            );
        }
    }
}

#[test]
fn prop_breakdown_monotone_in_bandwidth() {
    // More bandwidth never increases any split's total latency.
    let mut rng = Prng::new(0xBEEF);
    for case in 0..CASES {
        let p = random_profile(&mut rng);
        let lat = Duration::from_millis(rng.next_range(0, 50));
        let bw_lo = rng.next_f32_range(0.5, 20.0) as f64;
        let bw_hi = bw_lo * (1.0 + rng.next_f64() * 10.0);
        for k in 0..=p.layers.len() {
            let slow = p.breakdown(k, bw_lo, lat, 1.0).total();
            let fast = p.breakdown(k, bw_hi, lat, 1.0).total();
            assert!(fast <= slow, "case {case}: split {k} got faster on less bandwidth");
        }
    }
}

#[test]
fn prop_transfer_time_monotone() {
    let mut rng = Prng::new(0xC0FFEE);
    for case in 0..CASES {
        let lat = Duration::from_millis(rng.next_range(0, 100));
        let bw = rng.next_f32_range(0.1, 1000.0) as f64;
        let a = rng.next_range(0, 10_000_000) as usize;
        let b = a + rng.next_range(1, 1_000_000) as usize;
        assert!(
            transfer_time(a, bw, lat) <= transfer_time(b, bw, lat),
            "case {case}: more bytes took less time"
        );
        let bw2 = bw * 2.0;
        assert!(
            transfer_time(b, bw2, lat) <= transfer_time(b, bw, lat),
            "case {case}: more bandwidth took more time"
        );
    }
}

#[test]
fn prop_flow_conservation_and_bounds() {
    let mut rng = Prng::new(0xF00D);
    for case in 0..CASES {
        let window = Duration::from_millis(rng.next_range(0, 20_000));
        let fps = rng.next_f32_range(0.5, 60.0) as f64;
        let service = if rng.chance(0.3) {
            None
        } else {
            Some(Duration::from_millis(rng.next_range(1, 2_000)))
        };
        let cap = 1 + rng.next_below(32);
        let o = simulate_window(window, fps, service, cap);
        assert_eq!(
            o.arrivals,
            o.served + o.queued + o.dropped,
            "case {case}: conservation violated"
        );
        assert!(o.queued <= cap as u64, "case {case}: queue exceeded capacity");
        if service.is_none() {
            assert_eq!(o.served, 0, "case {case}: served without a server");
        }
        let dr = o.drop_rate();
        assert!((0.0..=1.0).contains(&dr), "case {case}: drop rate {dr}");
    }
}

#[test]
fn prop_flow_drops_monotone_in_fps() {
    // Within one service/window config, higher fps never reduces the
    // number of dropped frames (Figs 14/15 trend).
    let mut rng = Prng::new(0x5EED);
    for case in 0..CASES {
        let window = Duration::from_millis(rng.next_range(100, 10_000));
        let service = Some(Duration::from_millis(rng.next_range(10, 1_000)));
        let cap = 1 + rng.next_below(16);
        let f1 = rng.next_f32_range(1.0, 30.0) as f64;
        let f2 = f1 * (1.0 + rng.next_f64());
        let d1 = simulate_window(window, f1, service, cap).dropped;
        let d2 = simulate_window(window, f2, service, cap).dropped;
        assert!(d2 + 1 >= d1, "case {case}: fps {f1}->{f2} drops {d1}->{d2}");
    }
}

#[test]
fn prop_ledger_never_exceeds_total() {
    let mut rng = Prng::new(0x1ED6E4);
    for case in 0..CASES {
        let total = rng.next_f32_range(100.0, 10_000.0) as f64;
        let ledger = MemoryLedger::new(total);
        let mut live = Vec::new();
        for _ in 0..rng.next_range(1, 40) {
            if rng.chance(0.6) {
                let mb = rng.next_f32_range(1.0, 2_000.0) as f64;
                if let Ok(r) = ledger.reserve("x", mb) {
                    live.push(r);
                }
            } else if !live.is_empty() {
                live.swap_remove(rng.next_below(live.len()));
            }
            let in_use = ledger.in_use_mb();
            assert!(
                in_use <= total + 1e-6,
                "case {case}: {in_use} > total {total}"
            );
            let sum: f64 = live.iter().map(|r| r.mb).sum();
            assert!(
                (in_use - sum).abs() < 1e-6,
                "case {case}: ledger {in_use} != live sum {sum}"
            );
            assert!(ledger.peak_mb() + 1e-9 >= in_use, "case {case}: peak < in_use");
        }
    }
}

#[test]
fn prop_state_machine_no_resurrection() {
    // Whatever transition sequence is attempted, once Terminated a
    // pipeline state can never legally change again.
    use PipelineState::*;
    let all = [Initialising, Standby, Active, Paused, Draining, Terminated];
    let mut rng = Prng::new(0xDEAD);
    for case in 0..CASES {
        let mut s = Initialising;
        for _ in 0..50 {
            let next = all[rng.next_below(all.len())];
            if s.can_transition(next) {
                s = next;
            }
            if s == Terminated {
                for &t in &all {
                    assert!(
                        !s.can_transition(t),
                        "case {case}: resurrected to {t:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_batcher_conserves_frames() {
    let mut rng = Prng::new(0xBA7C4);
    for case in 0..CASES {
        let cap = 1 + rng.next_below(16);
        let dmax = 1 + rng.next_below(8);
        let b = Batcher::new(cap, dmax);
        let mut offered = 0u64;
        let mut accepted = 0u64;
        let mut drained = 0u64;
        for _ in 0..rng.next_range(1, 100) {
            if rng.chance(0.6) {
                offered += 1;
                let f = neukonfig::device::Frame {
                    id: offered,
                    captured_at: Duration::ZERO,
                    pixels: vec![],
                    shape: vec![1, 1, 1, 0],
                };
                if b.offer(f) == Offer::Accepted {
                    accepted += 1;
                }
            } else {
                drained += b.drain().len() as u64;
            }
            assert!(b.len() <= cap, "case {case}: queue over capacity");
            assert_eq!(
                accepted,
                drained + b.len() as u64,
                "case {case}: frames lost or duplicated"
            );
        }
    }
}

#[test]
fn prop_schedule_poll_consumes_in_order() {
    let mut rng = Prng::new(0x5CED);
    for case in 0..CASES {
        let n = rng.next_range(1, 20);
        let events: Vec<(Duration, f64)> = (0..n)
            .map(|_| {
                (
                    Duration::from_millis(rng.next_range(0, 10_000)),
                    rng.next_f32_range(1.0, 100.0) as f64,
                )
            })
            .collect();
        let mut sorted = events.clone();
        sorted.sort_by_key(|e| e.0);
        let mut sched = Schedule::new(events);
        let mut t = Duration::ZERO;
        let mut seen = 0;
        while !sched.is_done() {
            t += Duration::from_millis(rng.next_range(1, 3_000));
            if let Some(bw) = sched.poll(t) {
                // poll returns the LATEST event <= t; count how many are due.
                let due = sorted.iter().filter(|e| e.0 <= t).count();
                assert!(due > seen, "case {case}: poll fired without due events");
                assert_eq!(
                    bw, sorted[due - 1].1,
                    "case {case}: wrong latest event"
                );
                seen = due;
            }
        }
        assert_eq!(seen, sorted.len(), "case {case}: events lost");
    }
}

#[test]
fn prop_json_never_panics_and_roundtrips_numbers() {
    let mut rng = Prng::new(0x750A);
    // Fuzz: random byte soup must return Ok or Err, never panic.
    for _ in 0..CASES {
        let len = rng.next_below(64);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_below(94) + 32) as u8).collect();
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = json::parse(&s);
    }
    // Integers round-trip exactly through the parser.
    for case in 0..CASES {
        let v = rng.next_range(0, 1 << 52) as i64 - (1 << 51);
        let doc = format!("{{\"v\": {v}}}");
        let parsed = json::parse(&doc).unwrap();
        assert_eq!(parsed.get("v").as_i64(), Some(v), "case {case}");
    }
}

#[test]
fn prop_summary_percentiles_ordered() {
    let mut rng = Prng::new(0x57A75);
    for case in 0..CASES {
        let n = 1 + rng.next_below(500);
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 1000.0).collect();
        let s = Summary::of(&xs).unwrap();
        assert!(s.min <= s.p50 && s.p50 <= s.p95, "case {case}");
        assert!(s.p95 <= s.p99 && s.p99 <= s.max, "case {case}");
        assert!(s.min <= s.mean && s.mean <= s.max, "case {case}");
        let mut w = Welford::default();
        xs.iter().for_each(|&x| w.push(x));
        assert!((w.mean() - s.mean).abs() < 1e-9, "case {case}");
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(percentile_sorted(&sorted, 100.0), s.max, "case {case}");
    }
}
