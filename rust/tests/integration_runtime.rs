//! Integration: load real AOT artifacts, execute them via PJRT, and verify
//! the numbers against the JAX golden outputs. Requires `make artifacts`.

use std::sync::Arc;

use neukonfig::clock::Clock;
use neukonfig::models::{default_artifacts_dir, ArtifactIndex};
use neukonfig::runtime::{literal_from_f32, ChainExecutor, Domain, WeightStore};
use neukonfig::util::json;

fn artifacts() -> Option<ArtifactIndex> {
    ArtifactIndex::load(default_artifacts_dir()).ok()
}

fn golden(model_dir: &std::path::Path) -> json::Value {
    let text = std::fs::read_to_string(model_dir.join("golden.json")).expect("golden.json");
    json::parse(&text).expect("parse golden")
}

/// Full-chain execution on one domain must reproduce the JAX forward pass.
#[test]
fn full_chain_matches_jax_golden() {
    let Some(index) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model_name in ["vgg19", "mobilenetv2"] {
        let manifest = index.model(model_name).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        let domain = Domain::new("test", 1.0).unwrap();
        let chain = ChainExecutor::build(
            domain,
            &manifest,
            0..manifest.num_layers(),
            &weights,
        )
        .unwrap();

        let g = golden(&manifest.dir);
        let input_value = g.get("input_value").as_f64().unwrap() as f32;
        let numel: usize = manifest.input_shape.iter().product();
        let input = literal_from_f32(&manifest.input_shape, &vec![input_value; numel]).unwrap();

        let out = chain.run_raw(&input).unwrap();
        let values = out.to_vec::<f32>().unwrap();

        let want_shape: Vec<usize> = g
            .get("output_shape")
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(values.len(), want_shape.iter().product::<usize>());

        let want_sum = g.get("output_sum").as_f64().unwrap();
        let got_sum: f64 = values.iter().map(|&v| v as f64).sum();
        assert!(
            (got_sum - want_sum).abs() < 1e-3,
            "{model_name}: sum {got_sum} != golden {want_sum}"
        );

        for (i, want) in g.get("output_first8").as_array().unwrap().iter().enumerate() {
            let want = want.as_f64().unwrap();
            let got = values[i] as f64;
            assert!(
                (got - want).abs() < 1e-4 + want.abs() * 1e-3,
                "{model_name}[{i}]: {got} != {want}"
            );
        }
        println!("{model_name}: golden match (sum={got_sum:.6})");
    }
}

/// Splitting the chain at any point and running edge-then-cloud must give
/// the same output as the unsplit chain — the invariant that makes
/// repartitioning semantically free.
#[test]
fn partitioned_execution_equals_full() {
    let Some(index) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model("vgg19").unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let edge = Domain::new("edge", 1.0).unwrap();
    let cloud = Domain::new("cloud", 2.0).unwrap();
    let n = manifest.num_layers();

    let full = ChainExecutor::build(edge.clone(), &manifest, 0..n, &weights).unwrap();
    let numel: usize = manifest.input_shape.iter().product();
    let input = literal_from_f32(&manifest.input_shape, &vec![0.25f32; numel]).unwrap();
    let want = full.run_raw(&input).unwrap().to_vec::<f32>().unwrap();

    for split in [1, n / 2, n - 1] {
        let e = ChainExecutor::build(edge.clone(), &manifest, 0..split, &weights).unwrap();
        let c = ChainExecutor::build(cloud.clone(), &manifest, split..n, &weights).unwrap();
        let mid = e.run_raw(&input).unwrap();
        let got = c.run_raw(&mid).unwrap().to_vec::<f32>().unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-5 + w.abs() * 1e-4,
                "split {split} idx {i}: {g} != {w}"
            );
        }
    }
}

/// cpu_scale dilation lands on the clock, not on wall time.
#[test]
fn cpu_scale_dilates_timeline() {
    let Some(index) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model("mobilenetv2").unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let domain = Domain::new("edge", 1.0).unwrap();
    let chain = ChainExecutor::build(domain.clone(), &manifest, 0..3, &weights).unwrap();
    let numel: usize = manifest.input_shape.iter().product();
    let input = literal_from_f32(&manifest.input_shape, &vec![0.5f32; numel]).unwrap();

    let clock = Clock::simulated();
    // Warm up (first execution includes one-time allocation effects), then
    // take the best of several runs in each mode to suppress wall noise.
    for _ in 0..3 {
        chain.run_raw(&input).unwrap();
    }
    let best = |runs: usize, clock: &Clock| {
        (0..runs)
            .map(|_| chain.run(&input, clock).unwrap().1.total)
            .min()
            .unwrap()
    };
    let t_full = best(5, &clock);
    domain.set_cpu_scale(0.25);
    let t_stressed = best(5, &clock);
    // 4x dilation (with generous tolerance for wall-time noise).
    assert!(
        t_stressed > t_full.mul_f64(2.0),
        "stressed {t_stressed:?} !>> unstressed {t_full:?}"
    );
    assert!(clock.simulated_component() > std::time::Duration::ZERO);
}

/// Weight store slices must match the manifest offsets exactly.
#[test]
fn weights_cover_manifest() {
    let Some(index) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model_name in ["vgg19", "mobilenetv2"] {
        let manifest = index.model(model_name).unwrap();
        let weights = WeightStore::load(&manifest).unwrap();
        assert_eq!(weights.len(), manifest.weights_bytes);
        let mut offset = 0usize;
        for layer in &manifest.layers {
            for p in &layer.params {
                assert_eq!(p.offset_bytes, offset, "{model_name}/{}", p.name);
                offset += p.size_bytes;
                let lits = weights.layer_literals(layer).unwrap();
                assert_eq!(lits.len(), layer.params.len());
            }
        }
        assert_eq!(offset, manifest.weights_bytes);
    }
}

/// Two domains ("edge" and "cloud") can coexist in one process, each with
/// its own PJRT client and executables.
#[test]
fn two_domains_coexist() {
    let Some(index) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = index.model("mobilenetv2").unwrap();
    let weights = WeightStore::load(&manifest).unwrap();
    let edge = Domain::new("edge", 1.0).unwrap();
    let cloud = Domain::new("cloud", 2.0).unwrap();
    let a = ChainExecutor::build(edge, &manifest, 0..2, &weights).unwrap();
    let b = ChainExecutor::build(cloud, &manifest, 0..2, &weights).unwrap();
    let numel: usize = manifest.input_shape.iter().product();
    let input = literal_from_f32(&manifest.input_shape, &vec![0.1f32; numel]).unwrap();
    let va = a.run_raw(&input).unwrap().to_vec::<f32>().unwrap();
    let vb = b.run_raw(&input).unwrap().to_vec::<f32>().unwrap();
    assert_eq!(va, vb);
    let _ = Arc::new(());
}
