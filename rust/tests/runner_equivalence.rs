//! Property test: overlapped execution is a pure wall-clock optimisation.
//!
//! For random models, partition points, depths 1..8, and both stage modes,
//! every `PipelinedRunner` report must match sequential `Pipeline::infer`:
//!
//! * `output` bitwise-identical, in frame order;
//! * `t_transfer` bitwise-identical — the link is the timing authority for
//!   transfers and, frame sizes being equal, charges exactly
//!   `latency + bytes*8/bandwidth` on both paths;
//! * `t_edge`/`t_cloud` are *measured* PJRT wall time, which no two runs
//!   reproduce bit-for-bit — for them the property is structural: positive
//!   totals, per-layer vectors sized to the split, and per-layer sums
//!   bounded by the chain totals (boundary upload/readback is chain-level).
//!
//! `proptest` is unavailable offline, so cases come from the in-tree
//! deterministic PRNG; failure messages carry the case coordinates.
//!
//! Artifact-backed: skips when `make artifacts` has not run.

use std::time::Duration;

use neukonfig::coordinator::experiments::ExperimentSetup;
use neukonfig::coordinator::{PipelinedRunner, Placement, PipelineState};
use neukonfig::device::FrameSource;
use neukonfig::util::prng::Prng;

const BURST: usize = 6;
const SPLITS_PER_MODEL: usize = 3;
const DEPTHS_PER_SPLIT: usize = 3;

/// Per-layer timing vectors must be shaped by the split and sum to no more
/// than the chain totals (small epsilon for Duration::mul_f64 rounding).
fn check_layer_timing(
    rep: &neukonfig::coordinator::InferenceReport,
    split: usize,
    n: usize,
    ctx: &str,
) {
    assert_eq!(rep.edge_per_layer.len(), split, "{ctx}: edge per-layer arity");
    assert_eq!(rep.cloud_per_layer.len(), n - split, "{ctx}: cloud per-layer arity");
    let eps = Duration::from_micros(1) * (n as u32 + 1);
    let edge_sum: Duration = rep.edge_per_layer.iter().sum();
    let cloud_sum: Duration = rep.cloud_per_layer.iter().sum();
    assert!(
        edge_sum <= rep.t_edge + eps,
        "{ctx}: edge per-layer sum {edge_sum:?} > t_edge {:?}",
        rep.t_edge
    );
    assert!(
        cloud_sum <= rep.t_cloud + eps,
        "{ctx}: cloud per-layer sum {cloud_sum:?} > t_cloud {:?}",
        rep.t_cloud
    );
    assert!(rep.edge_per_layer.iter().all(|d| *d > Duration::ZERO) || split == 0);
    assert!(rep.cloud_per_layer.iter().all(|d| *d > Duration::ZERO) || split == n);
}

#[test]
fn pipelined_reports_match_sequential_across_models_splits_depths() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Prng::new(0x3A6E5);

    for model in setup.index.models.clone() {
        let env = setup.env(&model).unwrap();
        let n = env.manifest.num_layers();
        let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 7);
        let frames: Vec<_> = (0..BURST)
            .map(|i| env.frame_literal(&cam.frame(i as u64)).unwrap())
            .collect();

        // Random interior splits plus both degenerate boundaries (empty
        // edge chain / empty cloud chain) — the corners most likely to
        // break hand-off code.
        let mut splits = vec![0, n];
        for _ in 0..SPLITS_PER_MODEL {
            splits.push(rng.next_below(n + 1));
        }

        for split in splits {
            let p = env
                .build_pipeline(split, Placement::NewContainers)
                .unwrap();
            p.transition(PipelineState::Active).unwrap();

            let sequential: Vec<_> = frames.iter().map(|f| p.infer(f).unwrap()).collect();
            let expected: Vec<Vec<f32>> = sequential
                .iter()
                .map(|r| r.output.to_vec::<f32>().unwrap())
                .collect();
            for (i, rep) in sequential.iter().enumerate() {
                check_layer_timing(rep, split, n, &format!("{model} split {split} seq frame {i}"));
            }

            for _ in 0..DEPTHS_PER_SPLIT {
                let depth = 1 + rng.next_below(8);
                for runner in [PipelinedRunner::new(depth), PipelinedRunner::two_stage(depth)] {
                    let ctx = format!(
                        "{model} split {split} depth {depth} stages {:?}",
                        runner.stages
                    );
                    let piped = runner.run(&p, &frames).unwrap();
                    assert_eq!(piped.len(), frames.len(), "{ctx}: report count");
                    for (i, (rep, seq)) in piped.iter().zip(&sequential).enumerate() {
                        assert_eq!(
                            rep.output.to_vec::<f32>().unwrap(),
                            expected[i],
                            "{ctx}: frame {i} out of order or corrupted"
                        );
                        assert_eq!(
                            rep.t_transfer, seq.t_transfer,
                            "{ctx}: frame {i} transfer-time authority diverged"
                        );
                        assert!(rep.t_edge > Duration::ZERO || split == 0, "{ctx}: frame {i}");
                        assert!(rep.t_cloud > Duration::ZERO || split == n, "{ctx}: frame {i}");
                        check_layer_timing(rep, split, n, &format!("{ctx} frame {i}"));
                    }
                }
            }
        }
    }
}

/// The codec acceptance shape in miniature: the default fp32 codec is a
/// true identity (same bytes on the wire, zero encode/decode time, the
/// exact `latency + bytes*8/bandwidth` charge as ever), int8 shrinks the
/// wire by ~4x, and overlapped execution under a lossy codec still matches
/// sequential bit-for-bit (the codec is deterministic and the single
/// transfer-stage thread keeps the link queue empty).
#[test]
fn fp32_codec_is_duration_identical_and_int8_shrinks_wire() {
    use neukonfig::codec::TransferCodec;
    use neukonfig::netsim::transfer_time;

    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let model = &setup.index.models[0];
    let env = setup.env(model).unwrap();
    let n = env.manifest.num_layers();
    let split = (1..n)
        .max_by_key(|&k| env.manifest.transfer_bytes(k))
        .unwrap_or(n / 2);
    let raw = env.manifest.transfer_bytes(split);
    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 11);
    let frames: Vec<_> = (0..3)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();

    // Fp32: the identity codec. The link is idle between frames on the
    // simulated clock, so every charge is exactly the Equation-1 cost.
    let p32 = env.build_pipeline(split, Placement::NewContainers).unwrap();
    assert_eq!(p32.codec, TransferCodec::Fp32, "env default must be fp32");
    p32.transition(PipelineState::Active).unwrap();
    let rep32 = p32.infer(&frames[0]).unwrap();
    assert_eq!(rep32.raw_bytes, raw);
    assert_eq!(rep32.wire_bytes, raw);
    assert_eq!(rep32.t_encode, Duration::ZERO);
    assert_eq!(rep32.t_decode, Duration::ZERO);
    assert_eq!(rep32.compression_ratio(), 1.0);
    assert_eq!(
        rep32.t_transfer,
        transfer_time(raw, env.link.bandwidth_mbps(), env.link.latency()),
        "fp32 chunked transfer must be duration-identical to the whole-payload charge"
    );

    // Int8: quarters the wire (plus the quantisation header) and is
    // strictly cheaper on the same link.
    let mut p8 = env.build_pipeline(split, Placement::NewContainers).unwrap();
    p8.codec = TransferCodec::Int8;
    p8.transition(PipelineState::Active).unwrap();
    let rep8 = p8.infer(&frames[0]).unwrap();
    assert_eq!(rep8.raw_bytes, raw);
    assert_eq!(rep8.wire_bytes, raw / 4 + 16);
    assert!(rep8.compression_ratio() > 3.0, "ratio {}", rep8.compression_ratio());
    assert!(rep8.t_transfer < rep32.t_transfer);
    assert_eq!(
        rep8.output.to_vec::<f32>().unwrap().len(),
        rep32.output.to_vec::<f32>().unwrap().len(),
        "quantisation must not change the output shape"
    );

    // Overlapped-vs-sequential equivalence holds under a lossy codec too.
    let sequential: Vec<_> = frames.iter().map(|f| p8.infer(f).unwrap()).collect();
    let piped = PipelinedRunner::new(2).run(&p8, &frames).unwrap();
    assert_eq!(piped.len(), sequential.len());
    for (i, (pr, sr)) in piped.iter().zip(&sequential).enumerate() {
        assert_eq!(
            pr.output.to_vec::<f32>().unwrap(),
            sr.output.to_vec::<f32>().unwrap(),
            "frame {i}: overlapped int8 output diverged"
        );
        assert_eq!(pr.t_transfer, sr.t_transfer, "frame {i}: transfer authority diverged");
        assert_eq!(pr.wire_bytes, sr.wire_bytes);
        assert_eq!(pr.codec, TransferCodec::Int8);
    }
}

/// The hot_path acceptance shape in miniature: on a transfer-bound
/// realtime-clock configuration, three stages must not be slower than two
/// (the transfer of frame N overlaps both edge(N+1) and cloud(N-1)).
#[test]
fn three_stages_no_slower_than_two_when_transfer_bound() {
    let Ok(setup) = ExperimentSetup::load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let model = &setup.index.models[0];
    let manifest = setup.manifest(model).unwrap();
    // Realtime clock: simulated transfer cost becomes real wall time, so
    // stage overlap is observable. Sim costs zeroed so bring-up does not
    // really sleep. Bandwidth low enough that transfer dominates compute.
    let mut cfg = setup.cfg.clone().without_sim_costs();
    cfg.network.high_mbps = 2_000.0;
    let env = neukonfig::coordinator::EdgeCloudEnv::new(
        cfg,
        manifest,
        neukonfig::clock::Clock::realtime(),
    )
    .unwrap();
    let n = env.manifest.num_layers();
    // Split at the fattest intermediate tensor: maximises bytes on the wire.
    let split = (1..n)
        .max_by_key(|&k| env.manifest.transfer_bytes(k))
        .unwrap_or(n / 2);
    let p = env.build_pipeline(split, Placement::NewContainers).unwrap();
    p.transition(PipelineState::Active).unwrap();

    let cam = FrameSource::new(&env.manifest.input_shape, 15.0, 3);
    let frames: Vec<_> = (0..8)
        .map(|i| env.frame_literal(&cam.frame(i)).unwrap())
        .collect();

    let time = |runner: PipelinedRunner| {
        // Warm once, then best-of-3 (least-noise estimator).
        runner.run(&p, &frames).unwrap();
        (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                runner.run(&p, &frames).unwrap();
                t0.elapsed()
            })
            .min()
            .unwrap()
    };
    let two = time(PipelinedRunner::two_stage(2));
    let three = time(PipelinedRunner::new(2));
    // Generous slack: the property is "not slower", not a fixed speedup —
    // CI machines are noisy and compute may still dominate there.
    assert!(
        three <= two.mul_f64(1.25),
        "3-stage ({three:?}) should not be slower than 2-stage ({two:?}) \
         on a transfer-bound burst"
    );
}
