//! Model tests for the two concurrency protocols the paper's downtime
//! numbers rest on, in the style of the `loom` crate (the in-tree
//! `util::model` facade stands in — same API shape, schedule-perturbation
//! exploration instead of exhaustive DPOR; see its module docs).
//!
//! * The **runner hand-off**: bounded `sync_channel`s between pipeline
//!   stages, shutdown signalled by dropping the sender, per-frame drops
//!   marked in-band — every frame must be accounted processed-or-dropped
//!   and the shutdown must drain, not deadlock, at depth 1.
//! * The **router switch/rollback state machine**: probe-before-swap over
//!   [`PipelineState`], where a failed probe must leave the active
//!   pipeline untouched and retire the stillborn standby without it ever
//!   serving.
//!
//! CI's model-check job runs this suite with `RUSTFLAGS="--cfg loom"` and
//! `NEUKONFIG_MODEL_ITERS=2048`; the facade accepts the cfg (no code is
//! gated on it) so the command line is already loom-shaped if the real
//! crate lands.

use neukonfig::coordinator::PipelineState;
use neukonfig::util::model::{model, sync, thread};

use std::sync::atomic::{AtomicUsize, Ordering};

/// Mirror of the runner's staged hand-off payload: frame index + result,
/// with `None` marking a frame the transfer stage dropped in-band.
type Staged = (usize, Option<u64>);

/// The three-stage runner protocol at depth 1 — the satellite invariant:
/// shutdown (sender drop) drains the in-flight frames without deadlock,
/// and got + dropped == want afterwards.
#[test]
fn runner_three_stage_drains_on_shutdown_at_depth_1() {
    const FRAMES: usize = 6;
    // Frame 3 is "dropped by the transfer stage" (retry exhaustion in the
    // real runner): it must flow through as an in-band None, not stall the
    // pipeline.
    const DROPPED_FRAME: usize = 3;

    model(|| {
        let (edge_tx, edge_rx) = sync::mpsc::sync_channel::<Staged>(1);
        let (link_tx, link_rx) = sync::mpsc::sync_channel::<Staged>(1);

        let edge = thread::spawn(move || {
            for i in 0..FRAMES {
                if edge_tx.send((i, Some(i as u64 * 10))).is_err() {
                    return i;
                }
            }
            FRAMES
            // edge_tx drops here: the shutdown signal for the next stage.
        });

        let transfer = thread::spawn(move || {
            let mut forwarded = 0usize;
            while let Ok((i, staged)) = edge_rx.recv() {
                let out = if i == DROPPED_FRAME { None } else { staged };
                if link_tx.send((i, out)).is_err() {
                    return forwarded;
                }
                forwarded += 1;
            }
            forwarded
            // link_tx drops here, cascading the shutdown to the consumer.
        });

        // Cloud stage on the model's main thread, like the real runner
        // (PJRT executables are not Send).
        let mut got = Vec::new();
        let mut dropped = 0usize;
        while let Ok((i, staged)) = link_rx.recv() {
            match staged {
                Some(v) => got.push((i, v)),
                None => dropped += 1,
            }
        }

        let produced = edge.join().expect("edge stage panicked");
        let forwarded = transfer.join().expect("transfer stage panicked");
        assert_eq!(produced, FRAMES, "producer ran to completion");
        assert_eq!(forwarded, FRAMES, "transfer forwarded every hand-off");
        assert_eq!(
            got.len() + dropped,
            FRAMES,
            "every frame accounted processed-or-dropped"
        );
        assert_eq!(dropped, 1);
        // FIFO through both bounded hops: indices arrive in frame order.
        let indices: Vec<usize> = got.iter().map(|(i, _)| *i).collect();
        let mut sorted = indices.clone();
        sorted.sort_unstable();
        assert_eq!(indices, sorted, "frame order preserved");
        // And the payloads match their frames (no cross-slot smearing).
        for (i, v) in got {
            assert_eq!(v, i as u64 * 10);
        }
    });
}

/// Consumer aborts early (drops its receiver mid-stream, as the real
/// consumer does on a stage error): the producer must observe the hangup
/// as a send error and stop — never block forever on the full depth-1
/// channel.
#[test]
fn runner_producer_stops_on_consumer_hangup() {
    const FRAMES: usize = 8;
    const CONSUME: usize = 2;

    model(|| {
        let (tx, rx) = sync::mpsc::sync_channel::<Staged>(1);

        let producer = thread::spawn(move || {
            for i in 0..FRAMES {
                if tx.send((i, Some(0))).is_err() {
                    return i; // hangup observed — runner's early-exit path
                }
            }
            FRAMES
        });

        for _ in 0..CONSUME {
            rx.recv().expect("producer alive for the consumed prefix");
        }
        drop(rx); // consumer hit an error: hang up mid-stream

        let produced = producer.join().expect("producer panicked");
        // The producer stopped at or after the consumed prefix, strictly
        // before the full burst (the hangup cannot be outrun at depth 1).
        assert!(
            (CONSUME..FRAMES).contains(&produced),
            "producer stopped at {produced}, expected [{CONSUME}, {FRAMES})"
        );
    });
}

/// The router's probe-before-swap protocol over the real PipelineState
/// machine, with a concurrent traffic thread routing via the active slot:
/// every transition is legal, traffic only ever lands on a pipeline in a
/// serving state, and a failed probe leaves the old pipeline active while
/// the stillborn standby is retired without ever serving. Iterations
/// alternate probe success/failure so both arms race live traffic.
#[test]
fn router_switch_probe_rollback_state_machine() {
    use PipelineState::*;

    struct ModelPipeline {
        state: sync::Mutex<PipelineState>,
        served: AtomicUsize,
    }

    impl ModelPipeline {
        fn new(state: PipelineState) -> Self {
            ModelPipeline { state: sync::Mutex::new(state), served: AtomicUsize::new(0) }
        }

        /// Pipeline::transition, minus anyhow: panics on an illegal edge,
        /// which under the model checker is exactly what we want.
        fn transition(&self, to: PipelineState) {
            let mut s = self.state.lock().unwrap();
            assert!(s.can_transition(to), "illegal transition {s:?} -> {to:?}");
            *s = to;
        }

        fn infer(&self) {
            let s = *self.state.lock().unwrap();
            assert!(s.serves_traffic(), "routed a frame to a {s:?} pipeline");
            self.served.fetch_add(1, Ordering::Relaxed);
        }
    }

    // Deterministic per-iteration probe outcome (the model forbids
    // wall-clock and RNG): even iterations swap, odd ones roll back.
    let flip = std::sync::Arc::new(AtomicUsize::new(0));

    model(move || {
        let will_swap = flip.fetch_add(1, Ordering::Relaxed) % 2 == 0;
        let old = sync::Arc::new(ModelPipeline::new(Active));
        let new = sync::Arc::new(ModelPipeline::new(Initialising));
        // The router's active slot: an index into [old, new] behind the
        // model mutex, standing in for the router's RwLock'd Arc swap.
        let active = sync::Arc::new(sync::Mutex::new(0usize));

        // Traffic thread: routes frames at whatever the active slot says.
        // Like the real router it holds the slot guard across the route
        // (read lock held while picking the pipeline), so the swap cannot
        // retire a pipeline out from under an in-flight frame.
        let traffic = {
            let active = sync::Arc::clone(&active);
            let pipes = [sync::Arc::clone(&old), sync::Arc::clone(&new)];
            thread::spawn(move || {
                for _ in 0..4 {
                    let slot = active.lock().unwrap();
                    pipes[*slot].infer();
                }
            })
        };

        // Switch thread: bring the standby up, probe it, then either swap
        // or roll back — racing the traffic thread above.
        let switcher = {
            let old = sync::Arc::clone(&old);
            let new = sync::Arc::clone(&new);
            let active = sync::Arc::clone(&active);
            thread::spawn(move || {
                new.transition(Standby);
                // The probe runs via infer_unchecked (doesn't count as
                // serving); `will_swap` stands in for its outcome.
                if will_swap {
                    // Real router: new goes Active BEFORE the slot swap so
                    // traffic never lands on a non-serving pipeline...
                    new.transition(Active);
                    *active.lock().unwrap() = 1;
                    // ...and old drains only once it stops being routable
                    // (the swap's lock acquisition barriers with any
                    // in-flight route holding the guard).
                    old.transition(Draining);
                    old.transition(Terminated);
                } else {
                    // Rollback: slot untouched, stillborn standby retired
                    // (Standby -> Terminated) having never served.
                    new.transition(Terminated);
                }
            })
        };

        traffic.join().expect("traffic thread panicked");
        switcher.join().expect("switch thread panicked");

        let final_active = *active.lock().unwrap();
        if will_swap {
            assert_eq!(final_active, 1, "probe ok => slot points at new");
            assert_eq!(*new.state.lock().unwrap(), Active);
            assert_eq!(*old.state.lock().unwrap(), Terminated);
        } else {
            assert_eq!(final_active, 0, "rollback => slot untouched");
            assert_eq!(*old.state.lock().unwrap(), Active);
            assert_eq!(*new.state.lock().unwrap(), Terminated);
            assert_eq!(
                new.served.load(Ordering::Relaxed),
                0,
                "a stillborn pipeline never served a frame"
            );
        }
    });
}
