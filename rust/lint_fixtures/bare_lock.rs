//! Fixture: trips `bare_lock` (3 findings — same-line, split-chain, and
//! expect-variant). Exercised by rust/tests/lint_fixtures.rs and by
//! `cargo run --bin neukonfig_lint -- rust/lint_fixtures/bare_lock.rs`
//! (expected exit status: 1). Not compiled into the crate.

use std::sync::{Mutex, RwLock};

pub fn same_line(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

pub fn split_chain(m: &Mutex<u32>) -> u32 {
    *m
        .lock()
        .unwrap()
}

pub fn expect_variant(l: &RwLock<u32>) -> u32 {
    *l.read().expect("poisoned")
}
