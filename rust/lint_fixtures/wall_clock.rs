//! Fixture: trips `wall_clock` (2 findings). The string and comment
//! mentions of Instant::now() below must NOT count. Not compiled.

use std::time::{Instant, SystemTime};

pub fn stray_monotonic() -> Instant {
    // A comment saying Instant::now() is fine.
    let _doc = "so is Instant::now() in a string";
    Instant::now()
}

pub fn stray_wall() -> SystemTime {
    SystemTime::now()
}
