//! Fixture: trips `unbounded_channel` (2 findings). Lives under a
//! `coordinator/` path segment because the rule is scoped to coordinator
//! hand-off code; the bounded `sync_channel` below must NOT count.
//! Not compiled.

use std::sync::mpsc;

pub fn unbounded_handoff() -> (mpsc::Sender<u64>, mpsc::Receiver<u64>) {
    mpsc::channel()
}

pub fn unbounded_turbofish() {
    let (_tx, _rx) = mpsc::channel::<u64>();
}

pub fn bounded_is_fine() {
    let (_tx, _rx) = mpsc::sync_channel::<u64>(2);
}
