//! Fixture: trips `raw_sleep` (1 finding). Not compiled.

use std::time::Duration;

pub fn blocking_wait() {
    std::thread::sleep(Duration::from_millis(50));
}
