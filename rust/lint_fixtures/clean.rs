//! Fixture: clean file — zero findings expected, including the waived
//! wall-clock read (allow marker) and the test-only sleep. Not compiled.

use std::sync::Mutex;
use std::time::{Duration, Instant};

pub fn helper_style_lock(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

pub fn waived_wall_clock() -> Instant {
    // neukonfig_lint: allow(wall_clock) — fixture demonstrating the waiver
    Instant::now()
}

pub fn bounded_channel() {
    let (_tx, _rx) = std::sync::mpsc::sync_channel::<u32>(1);
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_sleep_and_unwrap() {
        let m = std::sync::Mutex::new(1u32);
        let _ = *m.lock().unwrap();
        std::thread::sleep(super::Duration::from_millis(1));
    }
}
