//! Fixture: trips `unsafe_code` (2 findings — a block and an unsafe fn).
//! The SAFETY comment on the first one does not help: the file is not on
//! the allowlist, and the rule requires BOTH. Not compiled.

pub fn reinterprets(data: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding and align(4) >= align(1).
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

pub unsafe fn raw_write(p: *mut u8) {
    *p = 0;
}
