"""L1 Pallas kernels (build-time only; lowered into per-layer HLO by aot.py)."""

from .conv2d import conv2d, pointwise_conv
from .depthwise import depthwise3x3
from .fused import bias_act
from .matmul import matmul

__all__ = ["conv2d", "pointwise_conv", "depthwise3x3", "bias_act", "matmul"]
