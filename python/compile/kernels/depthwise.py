"""L1 Pallas kernel: 3x3 depthwise convolution (MobileNetV2 hot path).

Depthwise conv has no channel contraction, so im2col+MXU is wasteful; the
TPU-idiomatic form is a VPU elementwise accumulation over the 9 taps with
channels on the lane axis. The grid tiles the channel dimension; each grid
step holds one (1, Hp, Wp, bc) input halo block in VMEM and writes one
(1, Ho, Wo, bc) output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _same_pad(dim: int, stride: int, k: int = 3) -> tuple[int, int, int]:
    """XLA SAME padding: (out_dim, pad_lo, pad_hi)."""
    out = -(-dim // stride)  # ceil
    total = max((out - 1) * stride + k - dim, 0)
    lo = total // 2
    return out, lo, total - lo


def _dw_kernel(x_ref, w_ref, o_ref, *, ho: int, wo: int, stride: int):
    # x_ref: (1, Hp, Wp, bc) SAME-padded input halo block
    # w_ref: (3, 3, bc) taps; o_ref: (1, Ho, Wo, bc)
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for di in range(3):
        for dj in range(3):
            tap = lax.slice(
                x,
                (0, di, dj, 0),
                (1, di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1, x.shape[3]),
                (1, stride, stride, 1),
            )
            acc += tap * w[di, dj, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "bc"))
def depthwise3x3(
    x: jax.Array, w: jax.Array, *, stride: int = 1, bc: int | None = None
) -> jax.Array:
    """3x3 depthwise convolution, SAME padding (XLA convention).

    x: (1, H, W, C) f32; w: (3, 3, C) f32 -> (1, Ho, Wo, C) with
    Ho = ceil(H/stride).
    """
    n, h, wdt, c = x.shape
    if n != 1:
        raise ValueError("depthwise3x3 is specialised for batch 1 (video frames)")
    if w.shape != (3, 3, c):
        raise ValueError(f"weight shape {w.shape} != (3, 3, {c})")
    bc = bc or min(LANE, _round_up(c, 8))
    cp = _round_up(c, bc)

    ho, plo_h, phi_h = _same_pad(h, stride)
    wo, plo_w, phi_w = _same_pad(wdt, stride)
    xp = jnp.pad(
        x, ((0, 0), (plo_h, phi_h), (plo_w, phi_w), (0, cp - c))
    ).astype(jnp.float32)
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c))).astype(jnp.float32)
    hp, wp_dim = xp.shape[1], xp.shape[2]

    out = pl.pallas_call(
        functools.partial(_dw_kernel, ho=ho, wo=wo, stride=stride),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((1, hp, wp_dim, bc), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((3, 3, bc), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda i: (0, 0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((1, ho, wo, cp), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:, :, :, :c]
