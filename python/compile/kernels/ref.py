"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has an entry here implemented only with
jnp/lax primitives (no Pallas). pytest (python/tests/) asserts allclose
between kernel and oracle across a hypothesis-driven shape/dtype sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def conv2d_ref(
    x: jax.Array, w: jax.Array, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def pointwise_conv_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    # (N,H,W,Cin) @ (Cin,Cout) over the channel axis.
    return jnp.einsum("nhwc,cd->nhwd", x.astype(jnp.float32), w.astype(jnp.float32))


def depthwise3x3_ref(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    c = x.shape[-1]
    # HWIO with feature_group_count=C: weight (3, 3, 1, C).
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32).reshape(3, 3, 1, c),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def bias_act_ref(x: jax.Array, b: jax.Array, *, act: str = "relu") -> jax.Array:
    y = x.astype(jnp.float32) + b.astype(jnp.float32)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "relu6":
        return jnp.clip(y, 0.0, 6.0)
    if act == "none":
        return y
    raise ValueError(f"unknown activation {act!r}")


def maxpool2x2_ref(x: jax.Array) -> jax.Array:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
