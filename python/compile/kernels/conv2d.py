"""L1: 2-D convolution lowered onto the Pallas matmul kernel via im2col.

VGG-19's conv layers (and MobileNetV2's stem) dominate edge-side compute.
On TPU the natural formulation is im2col + MXU matmul: the patch extraction
is a cheap gather/reshape the XLA CPU/TPU backend fuses, and the contraction
runs on the Pallas tiled kernel (``kernels.matmul``).

Layout: NHWC activations, HWIO weights — the JAX/TPU-native layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import matmul as mm


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """Convolution via im2col + Pallas matmul.

    x: (N, H, W, Cin) f32; w: (KH, KW, Cin, Cout) f32 -> (N, Ho, Wo, Cout).
    """
    n, h, width, cin = x.shape
    kh, kw, wcin, cout = w.shape
    if wcin != cin:
        raise ValueError(f"conv2d channel mismatch: x has {cin}, w has {wcin}")

    # (N, Ho, Wo, KH*KW*Cin) patches; XLA lowers this to a strided gather.
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, ho, wo, patch_dim = patches.shape
    # conv_general_dilated_patches emits features as Cin-major (C, KH, KW);
    # reorder the weight to match: (Cin, KH, KW, Cout).
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(kh * kw * cin, cout)
    lhs = patches.reshape(n * ho * wo, patch_dim)
    out = mm.matmul(lhs, wmat)
    return out.reshape(n, ho, wo, cout)


def pointwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """1x1 convolution — a pure matmul over the pixel axis.

    x: (N, H, W, Cin); w: (Cin, Cout) -> (N, H, W, Cout). This is the
    MobileNetV2 expand/project hot path.
    """
    n, h, width, cin = x.shape
    out = mm.matmul(x.reshape(n * h * width, cin), w)
    return out.reshape(n, h, width, -1)
