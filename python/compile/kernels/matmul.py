"""L1 Pallas kernel: tiled matrix multiply.

This is the compute hot-spot of both VGG-19 (conv-as-im2col + dense layers)
and MobileNetV2 (1x1 pointwise convs + classifier). The kernel is written
TPU-idiomatically — MXU-aligned 128x128 tiles, f32 accumulation in the
output block across the K grid dimension, VMEM-sized blocks expressed via
BlockSpec — and lowered with ``interpret=True`` so the XLA CPU backend used
by the Rust PJRT client can execute it (real-TPU lowering emits a Mosaic
custom-call the CPU plugin cannot run; see DESIGN.md §Hardware adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array native tile. Small layers fall back to the padded dim
# itself (rounded to the f32 sublane requirement) to avoid gross padding
# waste in interpret mode.
MXU_TILE = 128
SUBLANE = 8


def _block(dim: int, target: int = MXU_TILE) -> int:
    """Pick a block size for ``dim``: the MXU tile when the dim is big
    enough, otherwise the whole (sublane-rounded) dim."""
    if dim >= target:
        return target
    return max(SUBLANE, _round_up(dim, SUBLANE))


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(x_ref, y_ref, o_ref):
    # Grid is (M/bm, N/bn, K/bk); the output block index ignores the K
    # program id, so o_ref is revisited across K steps and accumulates.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """``x @ y`` via the Pallas tiled kernel.

    x: (M, K) f32, y: (K, N) f32 -> (M, N) f32. Inputs are zero-padded to
    block multiples (zero-padding K contributes nothing to the sum) and the
    output is sliced back, so arbitrary shapes are accepted.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    bm = bm or _block(m)
    bn = bn or _block(n)
    bk = bk or _block(k)

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))).astype(jnp.float32)
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n))).astype(jnp.float32)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def vmem_bytes(bm: int = MXU_TILE, bn: int = MXU_TILE, bk: int = MXU_TILE) -> int:
    """Estimated VMEM footprint of one grid step (f32), for DESIGN.md §Perf.

    Three resident blocks (x, y, o); double-buffering of the two inputs on a
    real TPU doubles their share.
    """
    f32 = 4
    single = (bm * bk + bk * bn + bm * bn) * f32
    double_buffered = (2 * (bm * bk + bk * bn) + bm * bn) * f32
    return double_buffered if single else single
