"""L1 Pallas kernel: fused bias-add + activation.

Every conv/dense layer in both models is followed by bias + ReLU (VGG) or
bias + ReLU6 (MobileNetV2, BN folded). Fusing them into one VPU pass avoids
an extra HBM round-trip of the activation tensor on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROWS = 256  # rows of the flattened activation per grid step


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _bias_act_kernel(x_ref, b_ref, o_ref, *, act: str):
    y = x_ref[...] + b_ref[...]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("act",))
def bias_act(x: jax.Array, b: jax.Array, *, act: str = "relu") -> jax.Array:
    """``act(x + b)`` with b broadcast over the trailing (channel) axis.

    x: (..., C) f32, b: (C,) f32. The leading axes are flattened into rows
    and tiled (ROWS x C-block) so arbitrary activation shapes stream through
    VMEM-sized blocks.
    """
    if b.ndim != 1 or x.shape[-1] != b.shape[0]:
        raise ValueError(f"bias shape {b.shape} does not match x {x.shape}")
    orig_shape = x.shape
    c = b.shape[0]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, c).astype(jnp.float32)

    bc = min(LANE, _round_up(c, 8))
    br = min(ROWS, _round_up(rows, 8))
    rp, cp = _round_up(rows, br), _round_up(c, bc)
    xp = jnp.pad(x2, ((0, rp - rows), (0, cp - c)))
    bp = jnp.pad(b, (0, cp - c)).astype(jnp.float32).reshape(1, cp)

    out = pl.pallas_call(
        functools.partial(_bias_act_kernel, act=act),
        grid=(rp // br, cp // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        interpret=True,
    )(xp, bp)
    return out[:rows, :c].reshape(orig_shape)
