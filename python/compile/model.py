"""L2: layer-granular JAX model definitions (build-time only).

NEUKONFIG partitions a DNN at a layer boundary and moves the split point at
runtime. To make every split point a first-class artifact, a model here is a
list of :class:`LayerSpec` *units* — one per valid partition point (layers
for VGG-19; blocks for MobileNetV2's parallel regions, following §II-A of
the paper). ``aot.py`` lowers each unit to its own HLO module; the Rust
runtime chains unit executables, so repartitioning is just "change the index
where execution moves from the edge chain to the cloud chain".

Each unit's ``apply`` has signature ``apply(x, *params) -> y`` with all
parameters as explicit runtime inputs (weights are fed by the Rust side from
``weights.bin``; baking them as HLO constants would bloat the text format
and hide the model-load cost the paper measures).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bias_act, conv2d, depthwise3x3, matmul, pointwise_conv
from .kernels.ref import maxpool2x2_ref


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass
class LayerSpec:
    """One partition unit: a layer (VGG) or a block (MobileNetV2)."""

    name: str
    kind: str  # conv | dense | maxpool | flatten | invres | gap | pwconv
    apply: Callable[..., jax.Array]
    params: list[ParamSpec]
    input_shape: tuple[int, ...]
    output_shape: tuple[int, ...]
    flops: int

    @property
    def output_bytes(self) -> int:
        return int(np.prod(self.output_shape)) * 4  # f32

    @property
    def param_bytes(self) -> int:
        return sum(p.size for p in self.params) * 4


@dataclasses.dataclass
class ModelSpec:
    name: str
    input_shape: tuple[int, ...]
    layers: list[LayerSpec]

    @property
    def total_flops(self) -> int:
        return sum(l.flops for l in self.layers)

    @property
    def total_param_bytes(self) -> int:
        return sum(l.param_bytes for l in self.layers)


def make_divisible(v: float, divisor: int = 8) -> int:
    """MobileNet channel rounding (keeps channels VPU-lane friendly)."""
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


def init_params(model: ModelSpec, seed: int = 0) -> list[list[np.ndarray]]:
    """He-initialised (seeded) parameters for every unit.

    The paper uses pre-trained Keras weights; those are unobtainable offline
    and accuracy is never part of the evaluation, so seeded random weights
    preserve everything that matters (shapes, bytes, compute). See DESIGN.md
    §Substitutions.
    """
    rng = np.random.default_rng(seed)
    out: list[list[np.ndarray]] = []
    for layer in model.layers:
        lp = []
        for p in layer.params:
            if p.name.endswith("_b"):
                lp.append(np.zeros(p.shape, np.float32))
            else:
                fan_in = int(np.prod(p.shape[:-1])) or 1
                std = math.sqrt(2.0 / fan_in)
                lp.append(rng.normal(0.0, std, p.shape).astype(np.float32))
        out.append(lp)
    return out


def forward(
    model: ModelSpec, params: Sequence[Sequence[jax.Array]], x: jax.Array
) -> jax.Array:
    """Full un-partitioned forward pass (test oracle for partition chains)."""
    for layer, lp in zip(model.layers, params):
        x = layer.apply(x, *lp)
    return x


# ---------------------------------------------------------------------------
# Unit constructors shared by vgg.py / mobilenetv2.py
# ---------------------------------------------------------------------------


def conv_unit(
    name: str,
    input_shape: tuple[int, ...],
    cout: int,
    *,
    stride: int = 1,
    act: str = "relu",
) -> LayerSpec:
    n, h, w, cin = input_shape
    ho, wo = -(-h // stride), -(-w // stride)

    def apply(x, wgt, b):
        return bias_act(conv2d(x, wgt, stride=stride), b, act=act)

    return LayerSpec(
        name=name,
        kind="conv",
        apply=apply,
        params=[
            ParamSpec(f"{name}_w", (3, 3, cin, cout)),
            ParamSpec(f"{name}_b", (cout,)),
        ],
        input_shape=input_shape,
        output_shape=(n, ho, wo, cout),
        flops=2 * 9 * cin * cout * ho * wo,
    )


def maxpool_unit(name: str, input_shape: tuple[int, ...]) -> LayerSpec:
    n, h, w, c = input_shape
    return LayerSpec(
        name=name,
        kind="maxpool",
        apply=lambda x: maxpool2x2_ref(x),
        params=[],
        input_shape=input_shape,
        output_shape=(n, h // 2, w // 2, c),
        flops=3 * (h // 2) * (w // 2) * c,
    )


def flatten_unit(name: str, input_shape: tuple[int, ...]) -> LayerSpec:
    n = input_shape[0]
    feat = int(np.prod(input_shape[1:]))
    return LayerSpec(
        name=name,
        kind="flatten",
        apply=lambda x: x.reshape(n, feat),
        params=[],
        input_shape=input_shape,
        output_shape=(n, feat),
        flops=0,
    )


def dense_unit(
    name: str,
    input_shape: tuple[int, ...],
    out_features: int,
    *,
    act: str = "relu",
    softmax: bool = False,
) -> LayerSpec:
    n, feat = input_shape

    def apply(x, wgt, b):
        y = bias_act(matmul(x, wgt), b, act=act)
        return jax.nn.softmax(y, axis=-1) if softmax else y

    return LayerSpec(
        name=name,
        kind="dense",
        apply=apply,
        params=[
            ParamSpec(f"{name}_w", (feat, out_features)),
            ParamSpec(f"{name}_b", (out_features,)),
        ],
        input_shape=input_shape,
        output_shape=(n, out_features),
        flops=2 * feat * out_features,
    )


def gap_unit(name: str, input_shape: tuple[int, ...]) -> LayerSpec:
    n, h, w, c = input_shape
    return LayerSpec(
        name=name,
        kind="gap",
        apply=lambda x: jnp.mean(x, axis=(1, 2)),
        params=[],
        input_shape=input_shape,
        output_shape=(n, c),
        flops=h * w * c,
    )


def invres_unit(
    name: str,
    input_shape: tuple[int, ...],
    cout: int,
    *,
    expand: int,
    stride: int,
) -> LayerSpec:
    """MobileNetV2 inverted-residual block as one partition unit.

    The parallel (residual) path means the interior is not a valid split
    point — the paper treats such regions as blocks (§II-A).
    """
    n, h, w, cin = input_shape
    cmid = cin * expand
    ho, wo = -(-h // stride), -(-w // stride)
    use_res = stride == 1 and cin == cout

    params = []
    if expand != 1:
        params += [
            ParamSpec(f"{name}_exp_w", (cin, cmid)),
            ParamSpec(f"{name}_exp_b", (cmid,)),
        ]
    params += [
        ParamSpec(f"{name}_dw_w", (3, 3, cmid)),
        ParamSpec(f"{name}_dw_b", (cmid,)),
    ]
    params += [
        ParamSpec(f"{name}_proj_w", (cmid, cout)),
        ParamSpec(f"{name}_proj_b", (cout,)),
    ]

    def apply(x, *p):
        i = 0
        y = x
        if expand != 1:
            y = bias_act(pointwise_conv(y, p[i]), p[i + 1], act="relu6")
            i += 2
        y = bias_act(depthwise3x3(y, p[i], stride=stride), p[i + 1], act="relu6")
        i += 2
        y = bias_act(pointwise_conv(y, p[i]), p[i + 1], act="none")
        return x + y if use_res else y

    flops = 0
    if expand != 1:
        flops += 2 * cin * cmid * h * w
    flops += 2 * 9 * cmid * ho * wo
    flops += 2 * cmid * cout * ho * wo

    return LayerSpec(
        name=name,
        kind="invres",
        apply=apply,
        params=params,
        input_shape=input_shape,
        output_shape=(n, ho, wo, cout),
        flops=flops,
    )


def pwconv_unit(
    name: str, input_shape: tuple[int, ...], cout: int, *, act: str = "relu6"
) -> LayerSpec:
    n, h, w, cin = input_shape

    def apply(x, wgt, b):
        return bias_act(pointwise_conv(x, wgt), b, act=act)

    return LayerSpec(
        name=name,
        kind="pwconv",
        apply=apply,
        params=[
            ParamSpec(f"{name}_w", (cin, cout)),
            ParamSpec(f"{name}_b", (cout,)),
        ],
        input_shape=input_shape,
        output_shape=(n, h, w, cout),
        flops=2 * cin * cout * h * w,
    )
