"""MobileNetV2 (non-sequential) as block-granular partition units — Fig 3.

MobileNetV2's inverted-residual blocks contain parallel (skip) paths, so
interior layers are not valid split points; following the paper (§II-A)
each such region is one block/unit. The unit list is: stem conv, 17
inverted-residual blocks, the final 1x1 conv, global average pooling, and
the classifier — 21 units.

Width multiplier (default 0.25, a standard MobileNetV2 alpha) and input
resolution (default 64) keep CPU-PJRT execution tractable while preserving
the compute-vs-transfer shape that moves the optimal split point.
"""

from __future__ import annotations

from .model import (
    LayerSpec,
    ModelSpec,
    conv_unit,
    dense_unit,
    gap_unit,
    invres_unit,
    make_divisible,
    pwconv_unit,
)

# (expansion t, output channels c, repeats n, first-stride s)
MBV2_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]
NUM_CLASSES = 1000


def build_mobilenetv2(
    *, width: float = 0.25, hw: int = 64, num_classes: int | None = None
) -> ModelSpec:
    num_classes = num_classes or max(16, int(NUM_CLASSES * width))
    layers: list[LayerSpec] = []

    shape = (1, hw, hw, 3)
    stem_c = make_divisible(32 * width)
    unit = conv_unit("stem", shape, stem_c, stride=2, act="relu6")
    layers.append(unit)
    shape = unit.output_shape

    block_i = 0
    for t, c, n, s in MBV2_CFG:
        cout = make_divisible(c * width)
        for rep in range(n):
            block_i += 1
            unit = invres_unit(
                f"block{block_i}",
                shape,
                cout,
                expand=t,
                stride=s if rep == 0 else 1,
            )
            layers.append(unit)
            shape = unit.output_shape

    head_c = make_divisible(1280 * width)
    unit = pwconv_unit("head", shape, head_c, act="relu6")
    layers.append(unit)
    shape = unit.output_shape

    unit = gap_unit("gap", shape)
    layers.append(unit)
    shape = unit.output_shape

    unit = dense_unit("classifier", shape, num_classes, act="none", softmax=True)
    layers.append(unit)

    return ModelSpec(name="mobilenetv2", input_shape=(1, hw, hw, 3), layers=layers)
