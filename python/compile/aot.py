"""AOT compile path: lower every partition unit to HLO text + manifest.

This is the only place Python touches the system; it runs once at build
time (``make artifacts``) and never on the request path. For each model it
emits::

    artifacts/<model>/layer_NN.hlo.txt   one HLO module per partition unit
    artifacts/<model>/weights.bin        flat little-endian f32 parameters
    artifacts/<model>/manifest.json      shapes / offsets / flops / bytes
    artifacts/manifest.json              index of models

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla_extension
0.5.1 bundled with the Rust ``xla`` crate rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .mobilenetv2 import build_mobilenetv2
from .model import ModelSpec, init_params
from .vgg import build_vgg19


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    ``return_tuple=False``: each unit returns one plain array, so the Rust
    side can chain device buffers between layer executables without a
    tuple-unwrap host readback per layer (EXPERIMENTS.md §Perf).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_layer(layer) -> str:
    x_spec = jax.ShapeDtypeStruct(layer.input_shape, jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in layer.params]

    def unit(x, *params):
        return layer.apply(x, *params)

    return to_hlo_text(jax.jit(unit).lower(x_spec, *p_specs))


def lower_fused(model: ModelSpec, lo: int, hi: int) -> str:
    """Lower units [lo, hi) as ONE fused HLO module.

    The ablation counterpart to the per-layer export: a fused partition
    gives XLA a whole-subgraph fusion scope but pins the split point at
    compile time — repartitioning then requires a fresh compile
    (rust/benches/ablation_fused.rs measures both sides of the trade).
    Parameter order: x, then every unit's params in declaration order.
    """
    layers = model.layers[lo:hi]
    x_spec = jax.ShapeDtypeStruct(layers[0].input_shape, jnp.float32)
    p_specs = [
        jax.ShapeDtypeStruct(p.shape, jnp.float32)
        for layer in layers
        for p in layer.params
    ]

    def unit(x, *params):
        i = 0
        for layer in layers:
            n = len(layer.params)
            x = layer.apply(x, *params[i : i + n])
            i += n
        return x

    return to_hlo_text(jax.jit(unit).lower(x_spec, *p_specs))


def export_fused(model: ModelSpec, mdir: pathlib.Path, splits: list[int]) -> list[dict]:
    """Export fused edge/cloud partition modules for the given splits."""
    entries = []
    n = len(model.layers)
    for k in splits:
        entry = {"split": k}
        if k > 0:
            name = f"fused_edge_{k:02d}.hlo.txt"
            (mdir / name).write_text(lower_fused(model, 0, k))
            entry["edge_hlo"] = name
        if k < n:
            name = f"fused_cloud_{k:02d}.hlo.txt"
            (mdir / name).write_text(lower_fused(model, k, n))
            entry["cloud_hlo"] = name
        entries.append(entry)
        print(f"  [{model.name}] fused split {k}", file=sys.stderr)
    return entries


def export_model(model: ModelSpec, out_root: pathlib.Path, seed: int) -> dict:
    mdir = out_root / model.name
    mdir.mkdir(parents=True, exist_ok=True)

    params = init_params(model, seed=seed)

    # weights.bin: concatenation of every unit's params in declaration order.
    offset = 0
    manifest_layers = []
    with open(mdir / "weights.bin", "wb") as wf:
        for i, (layer, lp) in enumerate(zip(model.layers, params)):
            pentries = []
            for spec, arr in zip(layer.params, lp):
                raw = np.ascontiguousarray(arr, dtype="<f4").tobytes()
                wf.write(raw)
                pentries.append(
                    {
                        "name": spec.name,
                        "shape": list(spec.shape),
                        "offset_bytes": offset,
                        "size_bytes": len(raw),
                    }
                )
                offset += len(raw)

            hlo_name = f"layer_{i:02d}.hlo.txt"
            hlo = lower_layer(layer)
            (mdir / hlo_name).write_text(hlo)
            manifest_layers.append(
                {
                    "index": i,
                    "name": layer.name,
                    "kind": layer.kind,
                    "hlo": hlo_name,
                    "input_shape": list(layer.input_shape),
                    "output_shape": list(layer.output_shape),
                    "output_bytes": layer.output_bytes,
                    "flops": layer.flops,
                    "params": pentries,
                }
            )
            print(
                f"  [{model.name}] {i:2d} {layer.name:12s} {layer.kind:8s} "
                f"out={layer.output_shape} hlo={len(hlo) // 1024}KiB",
                file=sys.stderr,
            )

    # Fused-partition ablation artifacts at the half split.
    fused = export_fused(model, mdir, [len(model.layers) // 2])

    manifest = {
        "name": model.name,
        "input_shape": list(model.input_shape),
        "weights_bin": "weights.bin",
        "weights_bytes": offset,
        "total_flops": model.total_flops,
        "layers": manifest_layers,
        "fused": fused,
    }
    (mdir / "manifest.json").write_text(json.dumps(manifest, indent=1))

    # Golden output for the Rust runtime's numeric verification: the full
    # forward pass on a constant 0.5 input.
    from .model import forward

    x = jnp.full(model.input_shape, 0.5, jnp.float32)
    y = np.asarray(forward(model, [[jnp.asarray(a) for a in lp] for lp in params], x))
    golden = {
        "input_value": 0.5,
        "output_shape": list(y.shape),
        "output_sum": float(y.sum()),
        "output_first8": [float(v) for v in y.flatten()[:8]],
    }
    (mdir / "golden.json").write_text(json.dumps(golden, indent=1))
    return manifest


def input_fingerprint() -> str:
    """Hash of every compile-path source file — lets `make` skip rebuilds."""
    here = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(here.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower NEUKONFIG models to HLO text")
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--width", type=float, default=0.25, help="channel width multiplier")
    ap.add_argument("--hw", type=int, default=64, help="input resolution")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--models", default="vgg19,mobilenetv2", help="comma-separated model list"
    )
    args = ap.parse_args()

    out_root = pathlib.Path(args.out)
    out_root.mkdir(parents=True, exist_ok=True)

    builders = {
        "vgg19": lambda: build_vgg19(width=args.width, hw=args.hw),
        "mobilenetv2": lambda: build_mobilenetv2(width=args.width, hw=args.hw),
    }

    index = {
        "width": args.width,
        "hw": args.hw,
        "seed": args.seed,
        "fingerprint": input_fingerprint(),
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        print(f"exporting {name} (width={args.width}, hw={args.hw})", file=sys.stderr)
        manifest = export_model(builders[name](), out_root, args.seed)
        index["models"][name] = {
            "manifest": f"{name}/manifest.json",
            "layers": len(manifest["layers"]),
            "weights_bytes": manifest["weights_bytes"],
        }

    (out_root / "manifest.json").write_text(json.dumps(index, indent=1))
    print(f"wrote {out_root}/manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()
