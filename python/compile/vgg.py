"""VGG-19 (sequential) as 25 partition units — mirrors the paper's Fig 2.

Keras VGG-19 has 25 partitionable layers: 16 convs, 5 max-pools, flatten and
3 dense layers. We keep the exact layer structure and scale the channel
widths (default 0.25x) and input resolution (default 64x64) so the whole
model is tractable on the CPU PJRT backend. The *relative* per-layer compute
and per-layer output sizes — which drive where the optimal split point falls
and how it moves with network speed — are preserved under uniform scaling
(DESIGN.md §Substitutions).
"""

from __future__ import annotations

from .model import (
    LayerSpec,
    ModelSpec,
    conv_unit,
    dense_unit,
    flatten_unit,
    maxpool_unit,
)

# Keras VGG-19 topology: conv channel counts with 'P' = 2x2 max-pool.
VGG19_CFG = [
    64, 64, "P",
    128, 128, "P",
    256, 256, 256, 256, "P",
    512, 512, 512, 512, "P",
    512, 512, 512, 512, "P",
]
FC_WIDTH = 4096
NUM_CLASSES = 1000


def build_vgg19(
    *, width: float = 0.25, hw: int = 64, num_classes: int | None = None
) -> ModelSpec:
    """Construct the width-scaled VGG-19 unit list."""
    num_classes = num_classes or max(16, int(NUM_CLASSES * width))
    layers: list[LayerSpec] = []
    shape = (1, hw, hw, 3)
    conv_i, pool_i = 0, 0
    for item in VGG19_CFG:
        if item == "P":
            pool_i += 1
            unit = maxpool_unit(f"pool{pool_i}", shape)
        else:
            conv_i += 1
            cout = max(8, int(item * width))
            unit = conv_unit(f"conv{conv_i}", shape, cout)
        layers.append(unit)
        shape = unit.output_shape

    unit = flatten_unit("flatten", shape)
    layers.append(unit)
    shape = unit.output_shape

    fc = max(64, int(FC_WIDTH * width))
    for i, (out, act, sm) in enumerate(
        [(fc, "relu", False), (fc, "relu", False), (num_classes, "none", True)], 1
    ):
        unit = dense_unit(f"fc{i}", shape, out, act=act, softmax=sm)
        layers.append(unit)
        shape = unit.output_shape

    return ModelSpec(name="vgg19", input_shape=(1, hw, hw, 3), layers=layers)
