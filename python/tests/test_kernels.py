"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes (and the deterministic cases pin the exact shapes the models
use) and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bias_act, conv2d, depthwise3x3, matmul, pointwise_conv
from compile.kernels import ref

KEY = jax.random.PRNGKey(42)
SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
)
def test_matmul_hypothesis(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7919 + k * 31 + n))
    x, y = rand(k1, (m, k)), rand(k2, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 512, 1024),  # VGG fc1 at 0.25x/64px
        (4096, 27, 16),  # VGG conv1 im2col
        (1024, 144, 16),  # conv after pool
        (256, 128, 128),  # MXU-aligned
        (128, 128, 128),
        (1, 1, 1),
        (129, 257, 127),  # off-tile
    ],
)
def test_matmul_model_shapes(m, k, n):
    k1, k2 = jax.random.split(KEY)
    x, y = rand(k1, (m, k)), rand(k2, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


def test_matmul_explicit_blocks():
    k1, k2 = jax.random.split(KEY)
    x, y = rand(k1, (100, 60)), rand(k2, (60, 80))
    out = matmul(x, y, bm=32, bn=16, bk=8)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_mismatch():
    with pytest.raises(ValueError):
        matmul(jnp.ones((2, 3)), jnp.ones((4, 5)))


def test_matmul_zero_padding_exact():
    # Padding K with zeros must not perturb the sum: identity check.
    x = jnp.eye(130, dtype=jnp.float32)
    y = rand(KEY, (130, 130))
    np.testing.assert_array_equal(matmul(x, y), np.asarray(y))


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    hw=st.integers(4, 32),
    cin=st.integers(1, 32),
    cout=st.integers(1, 32),
    stride=st.sampled_from([1, 2]),
)
def test_conv2d_hypothesis(hw, cin, cout, stride):
    k1, k2 = jax.random.split(jax.random.PRNGKey(hw * 131 + cin * 17 + cout + stride))
    x = rand(k1, (1, hw, hw, cin))
    w = rand(k2, (3, 3, cin, cout))
    np.testing.assert_allclose(
        conv2d(x, w, stride=stride),
        ref.conv2d_ref(x, w, stride=stride),
        rtol=1e-3,
        atol=1e-3,
    )


@pytest.mark.parametrize(
    "hw,cin,cout,stride",
    [
        (64, 3, 16, 1),  # VGG conv1
        (64, 16, 16, 1),
        (8, 128, 128, 1),  # VGG deep conv
        (64, 3, 8, 2),  # MBv2 stem
    ],
)
def test_conv2d_model_shapes(hw, cin, cout, stride):
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (1, hw, hw, cin))
    w = rand(k2, (3, 3, cin, cout))
    np.testing.assert_allclose(
        conv2d(x, w, stride=stride),
        ref.conv2d_ref(x, w, stride=stride),
        rtol=1e-3,
        atol=1e-3,
    )


def test_conv2d_channel_mismatch():
    with pytest.raises(ValueError):
        conv2d(jnp.ones((1, 8, 8, 3)), jnp.ones((3, 3, 4, 8)))


# ---------------------------------------------------------------------------
# pointwise (1x1) conv
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(hw=st.integers(1, 32), cin=st.integers(1, 64), cout=st.integers(1, 64))
def test_pointwise_hypothesis(hw, cin, cout):
    k1, k2 = jax.random.split(jax.random.PRNGKey(hw + cin * 101 + cout * 13))
    x = rand(k1, (1, hw, hw, cin))
    w = rand(k2, (cin, cout))
    np.testing.assert_allclose(
        pointwise_conv(x, w),
        ref.pointwise_conv_ref(x, w),
        rtol=1e-4,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# depthwise 3x3
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    hw=st.integers(3, 32),
    c=st.integers(1, 64),
    stride=st.sampled_from([1, 2]),
)
def test_depthwise_hypothesis(hw, c, stride):
    k1, k2 = jax.random.split(jax.random.PRNGKey(hw * 7 + c * 3 + stride))
    x = rand(k1, (1, hw, hw, c))
    w = rand(k2, (3, 3, c))
    np.testing.assert_allclose(
        depthwise3x3(x, w, stride=stride),
        ref.depthwise3x3_ref(x, w, stride=stride),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("hw,c,stride", [(32, 8, 1), (32, 48, 2), (4, 480, 1), (8, 96, 2)])
def test_depthwise_model_shapes(hw, c, stride):
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (1, hw, hw, c))
    w = rand(k2, (3, 3, c))
    np.testing.assert_allclose(
        depthwise3x3(x, w, stride=stride),
        ref.depthwise3x3_ref(x, w, stride=stride),
        rtol=1e-4,
        atol=1e-4,
    )


def test_depthwise_rejects_batch():
    with pytest.raises(ValueError):
        depthwise3x3(jnp.ones((2, 8, 8, 4)), jnp.ones((3, 3, 4)))


def test_depthwise_rejects_bad_weight():
    with pytest.raises(ValueError):
        depthwise3x3(jnp.ones((1, 8, 8, 4)), jnp.ones((3, 3, 5)))


# ---------------------------------------------------------------------------
# fused bias + activation
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    c=st.integers(1, 200),
    act=st.sampled_from(["relu", "relu6", "none"]),
)
def test_bias_act_hypothesis(rows, c, act):
    k1, k2 = jax.random.split(jax.random.PRNGKey(rows * 19 + c))
    x = rand(k1, (rows, c)) * 4.0  # exercise the relu6 clip
    b = rand(k2, (c,))
    np.testing.assert_allclose(
        bias_act(x, b, act=act), ref.bias_act_ref(x, b, act=act), rtol=1e-5, atol=1e-5
    )


def test_bias_act_4d():
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (1, 16, 16, 24))
    b = rand(k2, (24,))
    np.testing.assert_allclose(
        bias_act(x, b, act="relu6"),
        ref.bias_act_ref(x, b, act="relu6"),
        rtol=1e-5,
        atol=1e-5,
    )


def test_bias_act_relu6_saturates():
    x = jnp.full((4, 8), 100.0)
    b = jnp.zeros((8,))
    assert float(jnp.max(bias_act(x, b, act="relu6"))) == 6.0


def test_bias_act_rejects_mismatch():
    with pytest.raises(ValueError):
        bias_act(jnp.ones((2, 3)), jnp.ones((4,)))
