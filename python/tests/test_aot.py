"""AOT path: HLO text well-formedness, manifest/weights consistency.

These tests exercise the exact artifacts the Rust runtime consumes.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export_model, lower_layer, to_hlo_text
from compile.vgg import build_vgg19
from compile.model import init_params


@pytest.fixture(scope="module")
def tiny_model():
    return build_vgg19(width=0.0625, hw=32)


@pytest.fixture(scope="module")
def exported(tiny_model, tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    manifest = export_model(tiny_model, root, seed=0)
    return tiny_model, root, manifest


def test_hlo_text_is_parseable_module(tiny_model):
    hlo = lower_layer(tiny_model.layers[0])
    assert hlo.startswith("HloModule")
    assert "ENTRY" in hlo


def test_hlo_has_parameters(tiny_model):
    # conv unit: x + weight + bias = 3 parameters
    hlo = lower_layer(tiny_model.layers[0])
    for i in range(3):
        assert f"parameter({i})" in hlo
    assert f"parameter(3)" not in hlo


def test_export_writes_all_layers(exported):
    model, root, manifest = exported
    mdir = root / model.name
    assert len(manifest["layers"]) == len(model.layers)
    for entry in manifest["layers"]:
        assert (mdir / entry["hlo"]).exists()


def test_weights_bin_size_matches_manifest(exported):
    model, root, manifest = exported
    size = (root / model.name / "weights.bin").stat().st_size
    assert size == manifest["weights_bytes"]
    assert size == model.total_param_bytes


def test_manifest_offsets_contiguous(exported):
    _, _, manifest = exported
    offset = 0
    for entry in manifest["layers"]:
        for p in entry["params"]:
            assert p["offset_bytes"] == offset
            assert p["size_bytes"] == int(np.prod(p["shape"])) * 4
            offset += p["size_bytes"]
    assert offset == manifest["weights_bytes"]


def test_weights_roundtrip(exported):
    """Slicing weights.bin at manifest offsets reproduces init_params —
    exactly what the Rust weight store does."""
    model, root, manifest = exported
    blob = (root / model.name / "weights.bin").read_bytes()
    params = init_params(model, seed=0)
    for entry, lp in zip(manifest["layers"], params):
        for pmeta, arr in zip(entry["params"], lp):
            raw = blob[pmeta["offset_bytes"] : pmeta["offset_bytes"] + pmeta["size_bytes"]]
            got = np.frombuffer(raw, "<f4").reshape(pmeta["shape"])
            np.testing.assert_array_equal(got, arr)


def test_manifest_shapes_chain(exported):
    _, _, manifest = exported
    layers = manifest["layers"]
    for prev, nxt in zip(layers, layers[1:]):
        assert prev["output_shape"] == nxt["input_shape"]


def test_repo_artifacts_if_present():
    """Validate the real artifacts/ dir when it has been built."""
    root = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    idx = root / "manifest.json"
    if not idx.exists():
        pytest.skip("artifacts not built")
    index = json.loads(idx.read_text())
    for name, meta in index["models"].items():
        manifest = json.loads((root / meta["manifest"]).read_text())
        assert len(manifest["layers"]) == meta["layers"]
        assert (root / name / "weights.bin").stat().st_size == manifest["weights_bytes"]
        for entry in manifest["layers"]:
            assert (root / name / entry["hlo"]).exists()
