"""L2 correctness: model structure, shape chaining, partition semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.mobilenetv2 import build_mobilenetv2
from compile.model import forward, init_params, make_divisible
from compile.vgg import build_vgg19


@pytest.fixture(scope="module")
def vgg():
    return build_vgg19(width=0.125, hw=32)


@pytest.fixture(scope="module")
def mbv2():
    return build_mobilenetv2(width=0.25, hw=32)


def test_vgg_unit_count(vgg):
    # 16 convs + 5 pools + flatten + 3 dense = 25 partition points (Fig 2).
    assert len(vgg.layers) == 25
    kinds = [l.kind for l in vgg.layers]
    assert kinds.count("conv") == 16
    assert kinds.count("maxpool") == 5
    assert kinds.count("flatten") == 1
    assert kinds.count("dense") == 3


def test_mbv2_unit_count(mbv2):
    # stem + 17 inverted-residual blocks + head + gap + classifier = 21.
    assert len(mbv2.layers) == 21
    kinds = [l.kind for l in mbv2.layers]
    assert kinds.count("invres") == 17


def test_shapes_chain(vgg, mbv2):
    for model in (vgg, mbv2):
        for prev, nxt in zip(model.layers, model.layers[1:]):
            assert prev.output_shape == nxt.input_shape, (
                f"{model.name}: {prev.name} -> {nxt.name}"
            )


def test_flops_positive(vgg, mbv2):
    for model in (vgg, mbv2):
        for l in model.layers:
            if l.kind != "flatten":
                assert l.flops > 0, l.name


def test_param_bytes_match_shapes(vgg):
    for l in vgg.layers:
        assert l.param_bytes == sum(
            int(np.prod(p.shape)) * 4 for p in l.params
        )


def test_init_params_deterministic(vgg):
    a = init_params(vgg, seed=7)
    b = init_params(vgg, seed=7)
    for la, lb in zip(a, b):
        for pa, pb in zip(la, lb):
            np.testing.assert_array_equal(pa, pb)


def test_init_params_seed_changes(vgg):
    a = init_params(vgg, seed=1)
    b = init_params(vgg, seed=2)
    # Conv weights differ (biases are zero in both).
    assert not np.array_equal(a[0][0], b[0][0])


def test_forward_shapes(vgg, mbv2):
    for model in (vgg, mbv2):
        params = init_params(model)
        x = jnp.ones(model.input_shape, jnp.float32)
        y = forward(model, params, x)
        assert y.shape == model.layers[-1].output_shape
        # Final unit ends in softmax: probabilities sum to 1.
        np.testing.assert_allclose(float(y.sum()), 1.0, rtol=1e-5)


def test_partition_equivalence(vgg):
    """Executing layers 0..k then k..N equals the full forward — the
    invariant that makes repartitioning semantically free."""
    params = init_params(vgg)
    x = jax.random.normal(jax.random.PRNGKey(0), vgg.input_shape, jnp.float32)
    full = forward(vgg, params, x)
    for k in [1, 7, len(vgg.layers) - 1]:
        mid = x
        for layer, lp in zip(vgg.layers[:k], params[:k]):
            mid = layer.apply(mid, *lp)
        out = mid
        for layer, lp in zip(vgg.layers[k:], params[k:]):
            out = layer.apply(out, *lp)
        np.testing.assert_allclose(out, full, rtol=1e-4, atol=1e-6)


def test_make_divisible():
    assert make_divisible(8) == 8
    assert make_divisible(32 * 0.25) == 8
    assert make_divisible(24 * 0.25) == 8
    assert make_divisible(1280 * 0.25) == 320
    # never rounds below 90% of the requested value
    for v in [10, 17, 100, 333]:
        assert make_divisible(v) >= 0.9 * v


def test_invres_residual_only_when_legal(mbv2):
    for l in mbv2.layers:
        if l.kind == "invres":
            same_shape = l.input_shape == l.output_shape
            if not same_shape:
                continue
            # residual blocks must preserve shape
            assert l.input_shape[1:3] == l.output_shape[1:3]


def test_output_bytes(vgg):
    l = vgg.layers[0]
    assert l.output_bytes == int(np.prod(l.output_shape)) * 4
