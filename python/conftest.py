"""Ensure `python/` is importable so `pytest python/tests/` works from the
repository root as well as from `python/`."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
